//! Fault injection for shard workers — the test hook that makes the
//! failover paths provable.
//!
//! A plan counts *protocol frames* a worker writes on request streams
//! and shard replies (`ping`/`stats` replies don't count, so health
//! probes never consume the budget) and triggers at a deterministic
//! frame. The three faults cover the three distinct failure modes the
//! coordinator must survive:
//!
//! * **die** — the whole process goes silent: every connection severs
//!   without a terminal frame and new connections are accepted-then-
//!   dropped, so health probes see EOF. The coordinator must fail the
//!   lane over to a survivor.
//! * **stall** — frames keep flowing but each one takes `ms` longer.
//!   Not a death: the coordinator must NOT fail over (the request is
//!   still making progress) but must also not wedge — the engine's
//!   slow-consumer / deadline eviction bounds the damage.
//! * **drop** — one connection severs once, the worker stays healthy.
//!   Distinguishes "a socket died" from "the worker died".

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::error::{Error, Result};

/// What to inject, parsed from `--fault` (see [`FaultPlan::parse`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPlan {
    /// Permanently die when the K-th frame is about to be written.
    DieAfterFrames(u64),
    /// From the K-th frame on, sleep `ms` before every write.
    StallAfterFrames { frames: u64, ms: u64 },
    /// Sever the connection writing the K-th frame, once; the worker
    /// stays alive.
    DropAfterFrames(u64),
}

impl FaultPlan {
    /// Parse the `--fault` CLI syntax: `die_after=K`,
    /// `stall_after=K:MS`, `drop_after=K`.
    pub fn parse(s: &str) -> Result<Self> {
        let bad = || Error::Config(format!(
            "bad fault '{s}' (want die_after=K, stall_after=K:MS or drop_after=K)"
        ));
        let (kind, arg) = s.split_once('=').ok_or_else(bad)?;
        let num = |t: &str| t.parse::<u64>().map_err(|_| bad());
        match kind {
            "die_after" => Ok(FaultPlan::DieAfterFrames(num(arg)?)),
            "drop_after" => Ok(FaultPlan::DropAfterFrames(num(arg)?)),
            "stall_after" => {
                let (frames, ms) = arg.split_once(':').ok_or_else(bad)?;
                Ok(FaultPlan::StallAfterFrames { frames: num(frames)?, ms: num(ms)? })
            }
            _ => Err(bad()),
        }
    }
}

/// Shared per-server fault state ([`ServerOptions::fault`]); with no
/// plan the write-path hook is a single atomic load.
///
/// [`ServerOptions::fault`]: crate::server::ServerOptions
pub struct FaultState {
    plan: Option<FaultPlan>,
    frames: AtomicU64,
    dead: AtomicBool,
    dropped: AtomicBool,
}

impl FaultState {
    pub fn new(plan: Option<FaultPlan>) -> Self {
        Self {
            plan,
            frames: AtomicU64::new(0),
            dead: AtomicBool::new(false),
            dropped: AtomicBool::new(false),
        }
    }

    /// An injected death happened (all connections must go silent).
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Called immediately before each counted protocol frame write.
    /// Returns `false` if the connection must sever instead of
    /// writing (and, for a die plan, flips the whole worker dead).
    pub fn before_frame(&self) -> bool {
        let Some(plan) = self.plan else { return true };
        if self.is_dead() {
            return false;
        }
        let n = self.frames.fetch_add(1, Ordering::SeqCst) + 1;
        match plan {
            FaultPlan::DieAfterFrames(k) => {
                if n >= k {
                    self.dead.store(true, Ordering::SeqCst);
                    return false;
                }
                true
            }
            FaultPlan::StallAfterFrames { frames, ms } => {
                if n >= frames {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
                true
            }
            FaultPlan::DropAfterFrames(k) => {
                // One-shot: exactly the K-th frame severs its
                // connection; everything before and after flows.
                !(n == k && !self.dropped.swap(true, Ordering::SeqCst))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_syntax() {
        assert_eq!(FaultPlan::parse("die_after=5").unwrap(), FaultPlan::DieAfterFrames(5));
        assert_eq!(FaultPlan::parse("drop_after=7").unwrap(), FaultPlan::DropAfterFrames(7));
        assert_eq!(
            FaultPlan::parse("stall_after=3:250").unwrap(),
            FaultPlan::StallAfterFrames { frames: 3, ms: 250 }
        );
        for bad in ["die_after", "die_after=x", "stall_after=3", "explode=1", ""] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn none_never_triggers() {
        let f = FaultState::new(None);
        for _ in 0..100 {
            assert!(f.before_frame());
        }
        assert!(!f.is_dead());
    }

    #[test]
    fn die_is_permanent() {
        let f = FaultState::new(Some(FaultPlan::DieAfterFrames(3)));
        assert!(f.before_frame());
        assert!(f.before_frame());
        assert!(!f.before_frame(), "third frame dies");
        assert!(f.is_dead());
        assert!(!f.before_frame(), "stays dead");
    }

    #[test]
    fn drop_severs_exactly_once() {
        let f = FaultState::new(Some(FaultPlan::DropAfterFrames(2)));
        assert!(f.before_frame());
        assert!(!f.before_frame(), "second frame severs");
        assert!(!f.is_dead(), "the worker itself survives a drop");
        for _ in 0..10 {
            assert!(f.before_frame(), "later frames flow normally");
        }
    }

    #[test]
    fn stall_keeps_delivering() {
        let f = FaultState::new(Some(FaultPlan::StallAfterFrames { frames: 2, ms: 1 }));
        let t0 = std::time::Instant::now();
        for _ in 0..5 {
            assert!(f.before_frame());
        }
        assert!(!f.is_dead());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(4), "frames 2..=5 stall");
    }
}
