//! The shard coordinator: routes client requests across N `pallas`
//! worker processes and survives worker deaths mid-request.
//!
//! One coordinator serves the ordinary client line protocol (the same
//! frames [`crate::server::Server`] speaks) and spreads work over
//! workers along two composable axes:
//!
//! * **Lane sharding** (`layer_split == 1`): each request is forwarded
//!   whole to one worker; the event stream relays back with the wire
//!   `id` rewritten. Greedy requests are forwarded with
//!   `"checkpoint": true`, so the worker streams a boundary
//!   [`MemSnapshot`] per segment; the coordinator absorbs those as
//!   failover checkpoints. When the worker's connection severs before
//!   a terminal frame, the request re-admits on a survivor seeded from
//!   the newest *usable* checkpoint ([`usable_checkpoint`]) via
//!   `"resume_state"` — or, for sampled requests (whose RNG state is
//!   not in the snapshot), replays from segment 0 under the same seed.
//!   Either way duplicate frames are suppressed by segment index /
//!   token position, so the merged client stream is byte-identical to
//!   an uninterrupted run.
//! * **Layer-range sharding** (`layer_split > 1`): the model's layers
//!   split into contiguous ranges ([`ShardPlan`]); the coordinator
//!   drives one `shard_segment` call per (segment, range), handing
//!   activations across sockets and sampling locally with the engine's
//!   own [`GenDriver`] — the sequential oracle executed across
//!   processes. Each stage reply carries that range's post-segment
//!   state, so a dead stage reloads on a survivor via `shard_load` and
//!   recomputes only the in-flight stage call.
//!
//! Save/resume: lane-mode `"save": true` relays through; the worker's
//! `resume_token` is re-mapped into a coordinator-scoped token pinned
//! to that worker (worker-assigned tokens are not unique across the
//! fleet). Pipeline mode rejects save/resume-by-token; inline
//! `"resume_state"` works on both paths. Pipeline mode does not emit
//! client-facing `snapshot` frames.
//!
//! Admin commands beyond the standard set: `{"cmd": "shard_workers"}`
//! lists the fleet with liveness, `{"cmd": "shard_attach",
//! "addr": "..."}` registers a replacement worker at runtime.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::MemSnapshot;
use crate::config::{ExecMode, ModelConfig};
use crate::coordinator::engine::{ExitAction, GenDriver};
use crate::coordinator::{EngineStats, Event, GenerateRequest, Response, ResumeFrom};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::scheduler::{segment_tokens, RunStats};
use crate::server::{parse_request, render_done, render_event};
use crate::tensor::Tensor;
use crate::trace::{self, TraceEvent, TID_CONTROL};

use super::plan::ShardPlan;
use super::worker::{bits_value, floats_from_bits};

/// Idle-poll slice while relaying a lane stream (bounds how late a
/// deadline/shutdown check can fire).
const POLL: Duration = Duration::from_millis(100);
/// Per-call reply budget for pipeline stage commands; an elapse is
/// treated as a dead worker.
const STAGE_TIMEOUT: Duration = Duration::from_secs(10);
const CONNECT_TIMEOUT: Duration = Duration::from_secs(1);
/// Failover checkpoints retained per in-flight lane request. The
/// newest usable one is at most two behind the newest received (a
/// boundary snapshot precedes its segment's `segment`/`token` frames),
/// so three always suffice.
const KEEP_SNAPSHOTS: usize = 3;

/// Knobs for [`ShardCoordinator::start`].
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// Contiguous layer ranges per chain; 1 = pure lane sharding.
    pub layer_split: usize,
    /// Slack past a request's own `deadline_ms` before a silent worker
    /// is declared over-deadline (stall, not death: the request is
    /// cancelled, not failed over).
    pub deadline_grace: Duration,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        Self { layer_split: 1, deadline_grace: Duration::from_secs(2) }
    }
}

struct WorkerSlot {
    addr: String,
    alive: bool,
}

#[derive(Clone)]
enum CancelTarget {
    /// Lane request in flight on a worker under coordinator wire id
    /// `wid`: cancel/save relay there.
    Worker { addr: String, wid: u64 },
    /// Pipeline request driven by the coordinator itself.
    Flag(Arc<AtomicBool>),
}

struct Shared {
    cfg: ModelConfig,
    opts: CoordinatorOptions,
    /// Layer ranges of the pipeline axis (one whole-model range in
    /// lane mode).
    ranges: Vec<(usize, usize)>,
    stats: Arc<EngineStats>,
    workers: Mutex<Vec<WorkerSlot>>,
    rr: AtomicU64,
    /// Coordinator->worker wire ids / shard sids (fleet-unique, offset
    /// away from direct-client id ranges).
    next_wid: AtomicU64,
    next_client_id: AtomicU64,
    next_token: AtomicU64,
    /// Coordinator resume token -> (worker addr, worker token).
    tokens: Mutex<HashMap<u64, (String, u64)>>,
    registry: Mutex<HashMap<u64, CancelTarget>>,
    shutdown: AtomicBool,
}

impl Shared {
    fn refresh_gauge(&self, workers: &[WorkerSlot]) {
        self.stats.shard_workers.set(workers.iter().filter(|w| w.alive).count() as u64);
    }

    /// Round-robin over live workers; when none are live, re-probe the
    /// dead ones once (a restarted worker rejoins without an explicit
    /// `shard_attach`).
    fn pick(&self) -> Option<String> {
        let mut ws = self.workers.lock().unwrap();
        if !ws.iter().any(|w| w.alive) {
            for w in ws.iter_mut() {
                if !w.alive && ping_worker(&w.addr) {
                    w.alive = true;
                }
            }
        }
        let alive: Vec<&WorkerSlot> = ws.iter().filter(|w| w.alive).collect();
        self.stats.shard_workers.set(alive.len() as u64);
        if alive.is_empty() {
            return None;
        }
        let i = self.rr.fetch_add(1, Ordering::Relaxed) as usize % alive.len();
        Some(alive[i].addr.clone())
    }

    fn mark_dead(&self, addr: &str) {
        let mut ws = self.workers.lock().unwrap();
        for w in ws.iter_mut() {
            if w.addr == addr {
                w.alive = false;
            }
        }
        self.refresh_gauge(&ws);
    }

    fn is_alive(&self, addr: &str) -> bool {
        self.workers.lock().unwrap().iter().any(|w| w.addr == addr && w.alive)
    }

    fn attach(&self, addr: &str) -> usize {
        let mut ws = self.workers.lock().unwrap();
        match ws.iter_mut().find(|w| w.addr == addr) {
            Some(w) => w.alive = true,
            None => ws.push(WorkerSlot { addr: addr.to_string(), alive: true }),
        }
        self.refresh_gauge(&ws);
        ws.len()
    }

    fn workers_json(&self) -> Value {
        let ws = self.workers.lock().unwrap();
        Value::obj(vec![(
            "workers",
            Value::Arr(
                ws.iter()
                    .map(|w| {
                        Value::obj(vec![
                            ("addr", Value::Str(w.addr.clone())),
                            ("alive", Value::Bool(w.alive)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

/// Handle to a running coordinator.
pub struct ShardCoordinator {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ShardCoordinator {
    /// Start coordinating `workers` (each a `pallas worker` address)
    /// on `addr`. The worker count must form whole chains:
    /// `workers.len() % opts.layer_split == 0` ([`ShardPlan::new`]).
    pub fn start(
        cfg: ModelConfig,
        workers: &[String],
        addr: &str,
        opts: CoordinatorOptions,
    ) -> Result<Self> {
        let plan = ShardPlan::new(workers.len(), cfg.n_layers, opts.layer_split)?;
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stats = Arc::new(EngineStats::default());
        stats.shard_workers.set(workers.len() as u64);
        let shared = Arc::new(Shared {
            cfg,
            opts,
            ranges: plan.ranges,
            stats,
            workers: Mutex::new(
                workers
                    .iter()
                    .map(|a| WorkerSlot { addr: a.clone(), alive: true })
                    .collect(),
            ),
            rr: AtomicU64::new(0),
            next_wid: AtomicU64::new(10_000_000),
            next_client_id: AtomicU64::new(1),
            next_token: AtomicU64::new(0),
            tokens: Mutex::new(HashMap::new()),
            registry: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
        });
        let sh = shared.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if sh.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let sh2 = sh.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &sh2);
                });
            }
        });
        Ok(Self { addr: local, shared, accept_thread: Some(accept_thread) })
    }

    /// Live coordinator counters (`shard_routed`, `shard_failovers`,
    /// `shard_handoffs`, ... — the shard rows of [`EngineStats`]).
    pub fn stats(&self) -> Arc<EngineStats> {
        self.shared.stats.clone()
    }

    /// Block until a `{"cmd": "shutdown"}` frame stops the coordinator
    /// (the CLI foreground path).
    pub fn join(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Request shutdown and join the acceptor.
    pub fn stop(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr); // unblock accept()
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Failover checkpoint math (pure, unit-tested).
// ---------------------------------------------------------------------------

/// Token positions safely re-derivable on resume: the last *full*
/// segment boundary at or below what was forwarded. A worker can die
/// mid token batch; the partial tail past this point is regenerated by
/// the survivor and deduplicated.
pub(crate) fn resume_point(delivered: usize, seg: usize) -> usize {
    delivered / seg * seg
}

/// The newest checkpoint the coordinator can actually resume from.
/// `snap.segments = c` is usable iff
///
/// 1. every segment before `c` was already forwarded to the client
///    (`c <= max_seg + 1`) — a boundary snapshot precedes its own
///    `segment` frame, so the newest received may front-run the
///    stream, and resuming from it would leave a hole; and
/// 2. the tokens that feed segment `c` are known: still inside the
///    prompt (`c < s_p_abs`), or delivered decode tokens below the
///    resume point `rp`.
pub(crate) fn usable_checkpoint<'a>(
    snaps: &'a VecDeque<MemSnapshot>,
    max_seg: Option<usize>,
    s_p_abs: usize,
    seg: usize,
    rp: usize,
) -> Option<&'a MemSnapshot> {
    let next_expected = max_seg.map_or(0, |m| m + 1);
    snaps.iter().rev().find(|s| {
        s.segments <= next_expected
            && (s.segments < s_p_abs || (s.segments - s_p_abs) * seg < rp)
    })
}

/// The token stream a resumed request must re-feed after checkpoint
/// `c`: the unconsumed prompt tail (`c` inside the prompt) or the
/// delivered decode tokens from segment `c` on. `known` must be the
/// resume-point-truncated delivered list.
pub(crate) fn tail_tokens(
    c: usize,
    base_seg: usize,
    s_p_abs: usize,
    seg: usize,
    prompt: &[u32],
    known: &[u32],
) -> Vec<u32> {
    if c < s_p_abs {
        let mut t = prompt[(c - base_seg) * seg..].to_vec();
        t.extend_from_slice(known);
        t
    } else {
        known[(c - s_p_abs) * seg..].to_vec()
    }
}

// ---------------------------------------------------------------------------
// Worker plumbing.
// ---------------------------------------------------------------------------

struct WorkerConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

fn worker_connect(addr: &str, read_timeout: Duration) -> Result<WorkerConn> {
    let sa = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| Error::Request(format!("worker addr '{addr}' does not resolve")))?;
    let stream = TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT)?;
    stream.set_read_timeout(Some(read_timeout))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let writer = stream.try_clone()?;
    Ok(WorkerConn { reader: BufReader::new(stream), writer })
}

/// One request frame out, one reply line in (shard commands and control
/// relays). Returns the reply plus the total bytes moved.
fn wc_roundtrip(conn: &mut WorkerConn, text: &str) -> Result<(Value, usize)> {
    conn.writer.write_all(text.as_bytes())?;
    conn.writer.write_all(b"\n")?;
    let mut line = String::new();
    conn.reader.read_line(&mut line)?;
    if line.is_empty() {
        return Err(Error::Request("worker closed connection".into()));
    }
    let n = text.len() + 1 + line.len();
    Ok((Value::parse(&line)?, n))
}

fn ping_worker(addr: &str) -> bool {
    let Ok(mut conn) = worker_connect(addr, Duration::from_secs(1)) else {
        return false;
    };
    let ping = Value::obj(vec![("cmd", Value::Str("ping".into()))]).to_json();
    matches!(
        wc_roundtrip(&mut conn, &ping),
        Ok((reply, _)) if reply.get("ok").map(|v| v.as_bool().unwrap_or(false)).unwrap_or(false)
    )
}

/// Best-effort control relay (`cancel` / `save`) to a worker.
fn relay_cmd(addr: &str, cmd: &str, wid: u64) -> Result<Value> {
    let mut conn = worker_connect(addr, Duration::from_secs(1))?;
    let text = Value::obj(vec![
        ("cmd", Value::Str(cmd.into())),
        ("id", Value::Num(wid as f64)),
    ])
    .to_json();
    Ok(wc_roundtrip(&mut conn, &text)?.0)
}

fn error_frame(id: u64, msg: &str) -> String {
    Value::obj(vec![
        ("id", Value::Num(id as f64)),
        ("event", Value::Str("error".into())),
        ("error", Value::Str(msg.into())),
    ])
    .to_json()
}

fn frame_map(v: &Value) -> BTreeMap<String, Value> {
    v.as_obj().cloned().unwrap_or_default()
}

/// Clone a worker frame with the wire id rewritten to the client's.
fn rewritten(frame: &Value, client_id: u64) -> Value {
    let mut m = frame_map(frame);
    m.insert("id".into(), Value::Num(client_id as f64));
    Value::Obj(m)
}

// ---------------------------------------------------------------------------
// Connection handling.
// ---------------------------------------------------------------------------

fn handle_conn(stream: TcpStream, sh: &Shared) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = match Value::parse(&line) {
            Err(e) => {
                writeln!(writer, "{}", error_frame(0, &format!("bad frame: {e}")))?;
                continue;
            }
            Ok(v) => v,
        };
        if let Some(cmd) = v.get("cmd").and_then(|c| c.as_str().ok().map(String::from)) {
            if !handle_cmd(sh, &mut writer, &cmd, &v)? {
                break;
            }
            continue;
        }
        if !serve_request(sh, &mut writer, &v)? {
            break; // client gone mid-stream
        }
    }
    Ok(())
}

/// Control commands; returns false when the connection should close
/// (shutdown).
fn handle_cmd(sh: &Shared, writer: &mut TcpStream, cmd: &str, v: &Value) -> Result<bool> {
    match cmd {
        "shutdown" => {
            sh.shutdown.store(true, Ordering::SeqCst);
            writeln!(writer, "{}", Value::obj(vec![("ok", Value::Bool(true))]).to_json())?;
            // Unblock the acceptor (it only re-checks the flag per
            // connection); this conn's local addr IS the listen addr.
            if let Ok(local) = writer.local_addr() {
                let _ = TcpStream::connect(local);
            }
            return Ok(false);
        }
        "ping" => {
            writeln!(writer, "{}", Value::obj(vec![("ok", Value::Bool(true))]).to_json())?;
        }
        "stats" => writeln!(writer, "{}", sh.stats.to_json().to_json())?,
        "shard_workers" => writeln!(writer, "{}", sh.workers_json().to_json())?,
        "shard_attach" => match v.req("addr").and_then(Value::as_str) {
            Ok(addr) => {
                let n = sh.attach(addr);
                writeln!(
                    writer,
                    "{}",
                    Value::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("workers", Value::Num(n as f64)),
                    ])
                    .to_json()
                )?;
            }
            Err(e) => writeln!(writer, "{}", error_frame(0, &e.to_string()))?,
        },
        "cancel" | "save" => {
            let id = match v.get("id").map(Value::as_u64).transpose() {
                Ok(Some(id)) => id,
                _ => {
                    writeln!(writer, "{}", error_frame(0, &format!("{cmd} needs a numeric id")))?;
                    return Ok(true);
                }
            };
            let target = sh.registry.lock().unwrap().get(&id).cloned();
            let reply = match target {
                None => Value::obj(vec![
                    ("ok", Value::Bool(false)),
                    ("id", Value::Num(id as f64)),
                ])
                .to_json(),
                Some(CancelTarget::Flag(flag)) => {
                    if cmd == "cancel" {
                        flag.store(true, Ordering::SeqCst);
                        sh.stats.cancelled.inc();
                        Value::obj(vec![
                            ("ok", Value::Bool(true)),
                            ("id", Value::Num(id as f64)),
                        ])
                        .to_json()
                    } else {
                        error_frame(
                            id,
                            "save is not supported for layer-sharded (pipeline) requests",
                        )
                    }
                }
                Some(CancelTarget::Worker { addr, wid }) => match relay_cmd(&addr, cmd, wid) {
                    Ok(reply) => {
                        if cmd == "cancel" {
                            sh.stats.cancelled.inc();
                        }
                        rewritten(&reply, id).to_json()
                    }
                    Err(e) => error_frame(id, &format!("worker relay failed: {e}")),
                },
            };
            writeln!(writer, "{reply}")?;
        }
        other => {
            writeln!(writer, "{}", error_frame(0, &format!("unknown cmd '{other}'")))?;
        }
    }
    Ok(true)
}

/// Admit one inference request; returns false when the client
/// disconnected mid-stream.
fn serve_request(sh: &Shared, writer: &mut TcpStream, v: &Value) -> Result<bool> {
    let next_auto = || sh.next_client_id.fetch_add(1, Ordering::Relaxed);
    let req = match parse_request(v, next_auto) {
        Err(e) => {
            writeln!(writer, "{}", error_frame(0, &e.to_string()))?;
            return Ok(true);
        }
        Ok(req) => req,
    };
    let flag = Arc::new(AtomicBool::new(false));
    {
        let mut reg = sh.registry.lock().unwrap();
        if reg.contains_key(&req.id) {
            drop(reg);
            writeln!(
                writer,
                "{}",
                error_frame(req.id, &format!("id {} already in flight", req.id))
            )?;
            return Ok(true);
        }
        reg.insert(req.id, CancelTarget::Flag(flag.clone()));
    }
    let keep = if sh.opts.layer_split == 1 {
        serve_lane(sh, writer, v, &req, &flag)
    } else {
        serve_pipeline(sh, writer, &req, &flag)
    };
    sh.registry.lock().unwrap().remove(&req.id);
    keep
}

// ---------------------------------------------------------------------------
// Lane sharding: whole-request relay with snapshot failover.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct LaneState {
    /// Generated tokens forwarded to the client, in position order.
    delivered: Vec<u32>,
    /// Highest segment index forwarded.
    max_seg: Option<usize>,
    /// Last few boundary checkpoints ([`KEEP_SNAPSHOTS`]).
    snaps: VecDeque<MemSnapshot>,
    /// Worker deaths survived so far. While zero, terminal frames
    /// relay with only the id rewritten — byte-identical to a direct
    /// connection. After a failover the final attempt only saw the
    /// tail, so the `done` frame's `generated`/`tokens` are rebuilt
    /// from coordinator-side accounting.
    failovers: usize,
}

enum AttemptOutcome {
    /// A terminal frame (done / worker-reported error) was forwarded.
    Finished,
    /// The client's socket broke; the worker request was cancelled.
    ClientGone,
    /// The worker connection severed before a terminal frame.
    WorkerDied,
    /// The request's hard deadline passed with the worker silent.
    Deadline,
    /// Coordinator shutdown requested.
    Stopped,
}

fn serve_lane(
    sh: &Shared,
    writer: &mut TcpStream,
    original: &Value,
    req: &GenerateRequest,
    flag: &AtomicBool,
) -> Result<bool> {
    let client_id = req.id;
    let seg = sh.cfg.seg;
    let greedy = req.sampling.is_greedy();
    let started = Instant::now();
    let hard_deadline = req.deadline.map(|d| started + d + sh.opts.deadline_grace);
    let forward_snapshots = req.checkpoint;

    // Token-resume requests are pinned: the conversation lives on one
    // worker, under that worker's own token.
    let pinned: Option<(String, u64)> = match &req.resume {
        Some(ResumeFrom::Token(tok)) => match sh.tokens.lock().unwrap().get(tok) {
            Some(p) => Some(p.clone()),
            None => {
                writeln!(writer, "{}", error_frame(client_id, "unknown resume token"))?;
                return Ok(true);
            }
        },
        _ => None,
    };
    let base_seg = match &req.resume {
        Some(ResumeFrom::Snapshot(s)) => s.segments,
        _ => 0,
    };
    let s_p_abs =
        base_seg + segment_tokens(&sh.cfg, &req.prompt).map(|b| b.len()).unwrap_or(0);
    // Checkpoint-based failover needs a deterministic replay of the
    // tail, which greedy decode gives and seeded sampling does not
    // (the sampler's RNG state is not part of the snapshot) — sampled
    // requests fail over by full replay under the same seed instead.
    let checkpoint = greedy && pinned.is_none();

    sh.stats.requests.inc();
    sh.stats.tokens.add(req.prompt.len() as u64);

    let mut lane = LaneState::default();
    let max_attempts = sh.workers.lock().unwrap().len() * 2 + 4;
    for _attempt in 0..max_attempts {
        if flag.load(Ordering::SeqCst) {
            writeln!(writer, "{}", error_frame(client_id, "request cancelled"))?;
            return Ok(true);
        }
        let worker = match &pinned {
            Some((addr, _)) if sh.is_alive(addr) => addr.clone(),
            Some((addr, _)) => {
                writeln!(
                    writer,
                    "{}",
                    error_frame(
                        client_id,
                        &format!("worker {addr} holding this conversation is gone"),
                    )
                )?;
                return Ok(true);
            }
            None => match sh.pick() {
                Some(a) => a,
                None => {
                    writeln!(writer, "{}", error_frame(client_id, "no live shard workers"))?;
                    return Ok(true);
                }
            },
        };
        let wid = sh.next_wid.fetch_add(1, Ordering::Relaxed);

        // Build this attempt's frame: the original with the wire id
        // rewritten, plus checkpointing and (on failover) the resume
        // seed. With no usable checkpoint — or for sampled requests —
        // the original replays whole and duplicates are suppressed.
        let mut m = frame_map(original);
        m.insert("id".into(), Value::Num(wid as f64));
        if checkpoint {
            m.insert("checkpoint".into(), Value::Bool(true));
        }
        if let Some((_, wtok)) = &pinned {
            m.insert("resume".into(), Value::Num(*wtok as f64));
        }
        let mut base = 0usize;
        if checkpoint && !lane.snaps.is_empty() {
            let rp = resume_point(lane.delivered.len(), seg);
            if let Some(snap) = usable_checkpoint(&lane.snaps, lane.max_seg, s_p_abs, seg, rp)
            {
                let tail = tail_tokens(
                    snap.segments,
                    base_seg,
                    s_p_abs,
                    seg,
                    &req.prompt,
                    &lane.delivered[..rp],
                );
                m.insert("tokens".into(), Value::arr_u32(&tail));
                m.insert(
                    "max_new_tokens".into(),
                    Value::Num(req.max_new_tokens.saturating_sub(rp) as f64),
                );
                let state = snap.to_json();
                sh.stats.shard_handoffs.inc();
                sh.stats.shard_handoff_bytes.add(state.to_json().len() as u64);
                m.insert("resume_state".into(), state);
                base = rp;
            }
        }

        sh.registry
            .lock()
            .unwrap()
            .insert(client_id, CancelTarget::Worker { addr: worker.clone(), wid });
        sh.stats.shard_routed.inc();

        let mut conn = match worker_connect(&worker, POLL) {
            Ok(c) => c,
            Err(_) => {
                sh.mark_dead(&worker);
                continue; // never started: not a failover
            }
        };
        let text = Value::Obj(m).to_json();
        if conn
            .writer
            .write_all(text.as_bytes())
            .and_then(|()| conn.writer.write_all(b"\n"))
            .is_err()
        {
            sh.mark_dead(&worker);
            continue;
        }

        match relay_stream(
            sh,
            &mut conn,
            writer,
            client_id,
            base,
            hard_deadline,
            forward_snapshots,
            &worker,
            (s_p_abs - base_seg) * seg,
            &mut lane,
        ) {
            AttemptOutcome::Finished => return Ok(true),
            AttemptOutcome::ClientGone => {
                let _ = relay_cmd(&worker, "cancel", wid);
                return Ok(false);
            }
            AttemptOutcome::WorkerDied => {
                lane.failovers += 1;
                if pinned.is_some() {
                    sh.mark_dead(&worker);
                    writeln!(
                        writer,
                        "{}",
                        error_frame(
                            client_id,
                            &format!("worker {worker} holding this conversation died"),
                        )
                    )?;
                    return Ok(true);
                }
                sh.mark_dead(&worker);
                sh.stats.shard_failovers.inc();
                if trace::enabled() {
                    trace::record(TraceEvent {
                        name: "failover_resume",
                        ts_us: trace::now_us(),
                        dur_us: 0,
                        tid: TID_CONTROL,
                        args: vec![
                            ("id", Value::Num(client_id as f64)),
                            ("dead_worker", Value::Str(worker.clone())),
                            ("attempt", Value::Num(lane.failovers as f64)),
                            ("resumed_tokens", Value::Num(lane.delivered.len() as f64)),
                        ],
                    });
                }
                continue;
            }
            AttemptOutcome::Deadline => {
                let _ = relay_cmd(&worker, "cancel", wid);
                writeln!(
                    writer,
                    "{}",
                    error_frame(client_id, "deadline exceeded (worker stalled)")
                )?;
                return Ok(true);
            }
            AttemptOutcome::Stopped => {
                let _ = relay_cmd(&worker, "cancel", wid);
                writeln!(writer, "{}", error_frame(client_id, "coordinator shutting down"))?;
                return Ok(true);
            }
        }
    }
    writeln!(writer, "{}", error_frame(client_id, "failover attempts exhausted"))?;
    Ok(true)
}

/// Relay one worker attempt's event stream to the client, absorbing
/// checkpoints and suppressing frames already forwarded by an earlier
/// attempt.
#[allow(clippy::too_many_arguments)]
fn relay_stream(
    sh: &Shared,
    conn: &mut WorkerConn,
    writer: &mut TcpStream,
    client_id: u64,
    base: usize,
    hard_deadline: Option<Instant>,
    forward_snapshots: bool,
    worker_addr: &str,
    prompt_tokens: usize,
    lane: &mut LaneState,
) -> AttemptOutcome {
    let mut line = String::new();
    loop {
        match conn.reader.read_line(&mut line) {
            Ok(0) => return AttemptOutcome::WorkerDied,
            Ok(_) => {
                if !line.ends_with('\n') {
                    return AttemptOutcome::WorkerDied; // severed mid-frame
                }
                match relay_frame(
                    sh,
                    &line,
                    writer,
                    client_id,
                    base,
                    forward_snapshots,
                    worker_addr,
                    prompt_tokens,
                    lane,
                ) {
                    Ok(Some(outcome)) => return outcome,
                    Ok(None) => {}
                    Err(_) => return AttemptOutcome::WorkerDied, // corrupt frame
                }
                line.clear();
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // Idle poll tick: `line` may hold a partial frame — keep
                // it and continue reading.
                if sh.shutdown.load(Ordering::SeqCst) {
                    return AttemptOutcome::Stopped;
                }
                if let Some(hd) = hard_deadline {
                    if Instant::now() >= hd {
                        return AttemptOutcome::Deadline;
                    }
                }
            }
            Err(_) => return AttemptOutcome::WorkerDied,
        }
    }
}

/// Process one worker frame. `Ok(Some(..))` ends the attempt.
#[allow(clippy::too_many_arguments)]
fn relay_frame(
    sh: &Shared,
    line: &str,
    writer: &mut TcpStream,
    client_id: u64,
    base: usize,
    forward_snapshots: bool,
    worker_addr: &str,
    prompt_tokens: usize,
    lane: &mut LaneState,
) -> Result<Option<AttemptOutcome>> {
    let frame = Value::parse(line)?;
    let ev = frame.get("event").and_then(|e| e.as_str().ok()).unwrap_or("");
    let forward = |writer: &mut TcpStream, v: &Value| -> Option<AttemptOutcome> {
        if writeln!(writer, "{}", v.to_json()).is_err() {
            Some(AttemptOutcome::ClientGone)
        } else {
            None
        }
    };
    match ev {
        "snapshot" => {
            // Failover checkpoint: absorb (and count the hand-off).
            sh.stats.shard_handoffs.inc();
            sh.stats.shard_handoff_bytes.add(line.len() as u64);
            if trace::enabled() {
                trace::record(TraceEvent {
                    name: "snapshot_handoff",
                    ts_us: trace::now_us(),
                    dur_us: 0,
                    tid: TID_CONTROL,
                    args: vec![
                        ("id", Value::Num(client_id as f64)),
                        ("worker", Value::Str(worker_addr.into())),
                        ("bytes", Value::Num(line.len() as f64)),
                    ],
                });
            }
            if let Ok(snap) = MemSnapshot::from_json(frame.req("state")?) {
                lane.snaps.push_back(snap);
                while lane.snaps.len() > KEEP_SNAPSHOTS {
                    lane.snaps.pop_front();
                }
            }
            if forward_snapshots {
                return Ok(forward(writer, &rewritten(&frame, client_id)));
            }
            Ok(None)
        }
        "segment" => {
            let index = frame.req("index")?.as_usize()?;
            if lane.max_seg.is_some_and(|m| index <= m) {
                return Ok(None); // replayed by a failover attempt
            }
            lane.max_seg = Some(index);
            Ok(forward(writer, &rewritten(&frame, client_id)))
        }
        "token" => {
            let pos = base + frame.req("pos")?.as_usize()?;
            let token = frame.req("token")?.as_u32()?;
            if pos < lane.delivered.len() {
                return Ok(None); // already delivered before the failover
            }
            lane.delivered.push(token);
            let mut m = frame_map(&frame);
            m.insert("id".into(), Value::Num(client_id as f64));
            m.insert("pos".into(), Value::Num(pos as f64));
            Ok(forward(writer, &Value::Obj(m)))
        }
        "done" => {
            let mut m = frame_map(&frame);
            m.insert("id".into(), Value::Num(client_id as f64));
            if let Ok(gen) = frame.req("generated").and_then(Value::as_u32_vec) {
                // The attempt's `done` carries its full output; fold in
                // anything not individually streamed as `token` frames
                // so coordinator accounting is complete either way.
                for (i, t) in gen.iter().enumerate() {
                    if base + i >= lane.delivered.len() {
                        lane.delivered.push(*t);
                    }
                }
            }
            if lane.failovers > 0 {
                // The final attempt only generated the tail; restore
                // whole-request accounting.
                m.insert("generated".into(), Value::arr_u32(&lane.delivered));
                m.insert("tokens".into(), Value::Num(prompt_tokens as f64));
            }
            if let Some(wtok) = frame.get("resume_token").map(Value::as_u64).transpose()? {
                // Worker tokens are not fleet-unique: re-map into the
                // coordinator's namespace, pinned to this worker.
                let ct = sh.next_token.fetch_add(1, Ordering::Relaxed) + 1;
                sh.tokens
                    .lock()
                    .unwrap()
                    .insert(ct, (worker_addr.to_string(), wtok));
                m.insert("resume_token".into(), Value::Num(ct as f64));
            }
            sh.stats.generated_tokens.add(lane.delivered.len() as u64);
            Ok(Some(
                forward(writer, &Value::Obj(m)).unwrap_or(AttemptOutcome::Finished),
            ))
        }
        "error" => Ok(Some(
            forward(writer, &rewritten(&frame, client_id))
                .unwrap_or(AttemptOutcome::Finished),
        )),
        _ => Ok(forward(writer, &rewritten(&frame, client_id))),
    }
}

// ---------------------------------------------------------------------------
// Layer-range sharding: the coordinator drives the pipeline itself.
// ---------------------------------------------------------------------------

struct Stage {
    lo: usize,
    hi: usize,
    sid: u64,
    addr: String,
    conn: Option<WorkerConn>,
    /// Last known range state (shard_load seed after a stage death).
    state: Option<Value>,
}

fn serve_pipeline(
    sh: &Shared,
    writer: &mut TcpStream,
    req: &GenerateRequest,
    flag: &AtomicBool,
) -> Result<bool> {
    let client_id = req.id;
    let cfg = &sh.cfg;
    let started = Instant::now();

    if req.save_requested() || matches!(req.resume, Some(ResumeFrom::Token(_))) {
        writeln!(
            writer,
            "{}",
            error_frame(
                client_id,
                "save/resume tokens are not supported with layer sharding \
                 (use \"resume_state\")",
            )
        )?;
        return Ok(true);
    }
    let resume = match &req.resume {
        Some(ResumeFrom::Snapshot(s)) => {
            if s.n_layers != cfg.n_layers || s.d_model != cfg.d_model || s.seg != cfg.seg {
                writeln!(
                    writer,
                    "{}",
                    error_frame(client_id, "resume_state does not match the served model"),
                )?;
                return Ok(true);
            }
            Some(s.as_ref().clone())
        }
        _ => None,
    };
    let blocks = match segment_tokens(cfg, &req.prompt) {
        Ok(b) => b,
        Err(e) => {
            writeln!(writer, "{}", error_frame(client_id, &e.to_string()))?;
            return Ok(true);
        }
    };
    let base_seg = resume.as_ref().map_or(0, |s| s.segments);
    let s_p_abs = base_seg + blocks.len();

    sh.stats.requests.inc();
    sh.stats.shard_routed.inc();
    sh.stats.sequential_runs.inc();
    sh.stats.tokens.add(req.prompt.len() as u64);

    // One lane per layer range; sids are fleet-unique so ranges of one
    // request can share a worker without colliding.
    let mut stages: Vec<Stage> = sh
        .ranges
        .iter()
        .map(|&(lo, hi)| Stage {
            lo,
            hi,
            sid: sh.next_wid.fetch_add(1, Ordering::Relaxed),
            addr: String::new(),
            conn: None,
            state: resume.as_ref().map(|s| slice_snapshot(s, lo, hi).to_json()),
        })
        .collect();

    let mut driver = GenDriver::new(req, s_p_abs);
    let mut queue: VecDeque<Vec<u32>> = blocks.into();
    let mut idx = base_seg;
    let mut kept_logits: Vec<Tensor> = Vec::new();
    let mut finished = false;
    let mut client_gone = false;
    // Saturation across processes is fill-only: the per-cell energy
    // signals live inside the workers' sessions and are not shipped
    // over the hand-off protocol.
    let mut monitor = crate::quality::MemoryMonitor::new(cfg);
    if base_seg > 0 {
        monitor.observe(base_seg * cfg.seg, None);
    }

    'segments: while let Some(seg_tokens) = queue.pop_front() {
        if flag.load(Ordering::SeqCst) {
            writeln!(writer, "{}", error_frame(client_id, "request cancelled"))?;
            drop_stages(&mut stages);
            return Ok(true);
        }
        if let Some(d) = req.deadline {
            if started.elapsed() > d {
                writeln!(writer, "{}", error_frame(client_id, "deadline exceeded"))?;
                drop_stages(&mut stages);
                return Ok(true);
            }
        }
        // Hand the segment through every range in order.
        let mut carry: Option<(Value, Value)> = None; // (x_bits, x_shape)
        let mut logits: Option<Tensor> = None;
        for r in 0..stages.len() {
            let payload = match &carry {
                None => vec![("tokens", Value::arr_u32(&seg_tokens))],
                Some((bits, shape)) => {
                    vec![("x_bits", bits.clone()), ("x_shape", shape.clone())]
                }
            };
            let reply = match stage_exec(sh, &mut stages[r], payload) {
                Ok(reply) => reply,
                Err(e) => {
                    writeln!(writer, "{}", error_frame(client_id, &e.to_string()))?;
                    drop_stages(&mut stages);
                    return Ok(true);
                }
            };
            if stages[r].hi == cfg.n_layers {
                let floats = floats_from_bits(reply.req("logits_bits")?)?;
                logits = Some(Tensor::new(&[cfg.seg, cfg.vocab], floats)?);
            } else {
                carry = Some((
                    reply.req("x_bits")?.clone(),
                    reply.req("x_shape")?.clone(),
                ));
            }
        }
        let logits = logits.ok_or_else(|| {
            Error::Schedule("pipeline ended without a final-range stage".into())
        })?;
        if req.want_logits {
            kept_logits.push(logits.clone());
        }

        // The engine's own decode state machine, driven across
        // processes: emits SegmentDone/Token, decides the next feed.
        let mut emit = |ev: Event| {
            if client_gone {
                return;
            }
            if writeln!(writer, "{}", render_event(client_id, &ev).to_json()).is_err() {
                client_gone = true;
            }
        };
        monitor.observe(cfg.seg, None);
        let action = driver.on_exit(idx, &logits, monitor.saturation(), &mut emit);
        idx += 1;
        if client_gone {
            drop_stages(&mut stages);
            return Ok(false);
        }
        match action {
            ExitAction::Wait => {}
            ExitAction::Feed(next) => queue.push_back(next),
            ExitAction::Finish => {
                finished = true;
                break 'segments;
            }
        }
    }
    let _ = finished; // prefill-only requests drain the queue instead

    let segments_run = idx - base_seg;
    let launches = (segments_run * stages.len()) as u64;
    let cells = (segments_run * cfg.n_layers) as u64;
    let resp = Response {
        id: client_id,
        greedy_tail: driver.last_greedy.clone(),
        generated: driver.generated.clone(),
        logits: None,
        reused_segments: base_seg,
        segments_skipped: 0,
        overflow_routed: false,
        saturation: monitor.saturation(),
        resume_token: None,
        final_state: None,
        mode_used: ExecMode::Sequential,
        stats: RunStats {
            mode_diagonal: false,
            segments: segments_run,
            launches,
            cells,
            slot_steps: cells,
            padded_cells: 0,
            wall: started.elapsed(),
            tokens: req.prompt.len(),
        },
        latency: started.elapsed(),
        trace: req.trace,
    };
    sh.stats.generated_tokens.add(resp.generated.len() as u64);
    let mut done = frame_map(&render_done(&resp));
    if req.want_logits {
        // Raw bit patterns per computed segment — the parity gate's
        // strongest signal (norms alone can mask bit drift).
        done.insert(
            "logits_bits".into(),
            Value::Arr(kept_logits.iter().map(|t| bits_value(t.data())).collect()),
        );
    }
    drop_stages(&mut stages);
    if writeln!(writer, "{}", Value::Obj(done).to_json()).is_err() {
        return Ok(false);
    }
    Ok(true)
}

/// Run one `shard_segment` call on a stage, reconnecting (and
/// reloading the range state onto a survivor) when its worker dies.
fn stage_exec(
    sh: &Shared,
    stage: &mut Stage,
    payload: Vec<(&str, Value)>,
) -> Result<Value> {
    let mut m: BTreeMap<String, Value> =
        payload.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    m.insert("cmd".into(), Value::Str("shard_segment".into()));
    m.insert("sid".into(), Value::Num(stage.sid as f64));
    let text = Value::Obj(m).to_json();

    let max_attempts = sh.workers.lock().unwrap().len() * 2 + 4;
    for _ in 0..max_attempts {
        if stage.conn.is_none() {
            let Some(addr) = sh.pick() else {
                return Err(Error::Request("no live shard workers".into()));
            };
            let Ok(mut conn) = worker_connect(&addr, STAGE_TIMEOUT) else {
                sh.mark_dead(&addr);
                continue;
            };
            // (Re)create the range lane — fresh, or seeded with the
            // last state this stage reported (the failover hand-off).
            let init = match &stage.state {
                Some(state) => Value::obj(vec![
                    ("cmd", Value::Str("shard_load".into())),
                    ("sid", Value::Num(stage.sid as f64)),
                    ("lo", Value::Num(stage.lo as f64)),
                    ("hi", Value::Num(stage.hi as f64)),
                    ("state", state.clone()),
                ]),
                None => Value::obj(vec![
                    ("cmd", Value::Str("shard_init".into())),
                    ("sid", Value::Num(stage.sid as f64)),
                    ("lo", Value::Num(stage.lo as f64)),
                    ("hi", Value::Num(stage.hi as f64)),
                ]),
            };
            match wc_roundtrip(&mut conn, &init.to_json()) {
                Ok((reply, n)) => {
                    if let Some(msg) = reply.get("error") {
                        return Err(Error::Request(format!(
                            "worker refused the range lane: {}",
                            msg.as_str().unwrap_or("?")
                        )));
                    }
                    if stage.state.is_some() {
                        sh.stats.shard_handoffs.inc();
                        sh.stats.shard_handoff_bytes.add(n as u64);
                    }
                    stage.addr = addr;
                    stage.conn = Some(conn);
                }
                Err(_) => {
                    sh.mark_dead(&addr);
                    sh.stats.shard_failovers.inc();
                    continue;
                }
            }
        }
        let conn = stage.conn.as_mut().expect("just ensured");
        match wc_roundtrip(conn, &text) {
            Ok((reply, n)) => {
                if let Some(msg) = reply.get("error") {
                    return Err(Error::Request(format!(
                        "shard stage [{}, {}) failed: {}",
                        stage.lo,
                        stage.hi,
                        msg.as_str().unwrap_or("?")
                    )));
                }
                sh.stats.shard_handoffs.inc();
                sh.stats.shard_handoff_bytes.add(n as u64);
                if let Some(st) = reply.get("state") {
                    stage.state = Some(st.clone());
                }
                return Ok(reply);
            }
            Err(_) => {
                let addr = stage.addr.clone();
                sh.mark_dead(&addr);
                sh.stats.shard_failovers.inc();
                stage.conn = None;
            }
        }
    }
    Err(Error::Request("shard stage failover attempts exhausted".into()))
}

fn drop_stages(stages: &mut [Stage]) {
    for stage in stages {
        if let Some(conn) = stage.conn.as_mut() {
            let drop = Value::obj(vec![
                ("cmd", Value::Str("shard_drop".into())),
                ("sid", Value::Num(stage.sid as f64)),
            ]);
            let _ = wc_roundtrip(conn, &drop.to_json());
        }
    }
}

/// A contiguous layer slice of a full snapshot, in the range-snapshot
/// convention (`n_layers = hi - lo`) the workers load.
fn slice_snapshot(full: &MemSnapshot, lo: usize, hi: usize) -> MemSnapshot {
    MemSnapshot {
        model: full.model.clone(),
        n_layers: hi - lo,
        d_model: full.d_model,
        phi_dim: full.phi_dim,
        seg: full.seg,
        segments: full.segments,
        a: full.a[lo..hi].to_vec(),
        z: full.z[lo..hi].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InferenceEngine;
    use crate::model::{NativeBackend, Params};
    use crate::scheduler::StepBackend;
    use crate::server::{Client, Server, ServerOptions};

    fn snap(segments: usize) -> MemSnapshot {
        MemSnapshot {
            model: "m".into(),
            n_layers: 1,
            d_model: 1,
            phi_dim: 1,
            seg: 4,
            segments,
            a: vec![Tensor::zeros(&[1, 1])],
            z: vec![Tensor::zeros(&[1])],
        }
    }

    #[test]
    fn checkpoint_usability_rules() {
        let seg = 4;
        let s_p = 2; // 2 prompt segments
        let snaps: VecDeque<MemSnapshot> = [1, 2, 3].into_iter().map(snap).collect();

        // Nothing forwarded yet: no checkpoint is usable (resuming
        // would skip SegmentDone frames the client never saw).
        assert!(usable_checkpoint(&snaps, None, s_p, seg, 0).is_none());
        // SegmentDone(0) forwarded, no tokens: only c=1 is usable.
        let got = usable_checkpoint(&snaps, Some(0), s_p, seg, 0).unwrap();
        assert_eq!(got.segments, 1);
        // Both prompt segments forwarded, 4 decode tokens delivered:
        // c=3 needs (3-2)*4=4 < 4 — not yet; c=2 wins.
        let got = usable_checkpoint(&snaps, Some(2), s_p, seg, 4).unwrap();
        assert_eq!(got.segments, 2);
        // 8 tokens delivered and SegmentDone(2) forwarded: c=3 usable.
        let got = usable_checkpoint(&snaps, Some(2), s_p, seg, 8).unwrap();
        assert_eq!(got.segments, 3);
    }

    #[test]
    fn tail_reconstruction() {
        let seg = 4;
        let prompt: Vec<u32> = (0..7).collect(); // 2 segments, last padded
        let s_p = 2;
        // Checkpoint inside the prompt: remaining raw prompt tail.
        assert_eq!(tail_tokens(1, 0, s_p, seg, &prompt, &[]), vec![4, 5, 6]);
        // Checkpoint at the prompt/decode boundary: delivered tokens.
        let known = [10, 11, 12, 13, 14, 15, 16, 17];
        assert_eq!(tail_tokens(2, 0, s_p, seg, &prompt, &known), known.to_vec());
        // One decode segment consumed: its successor's tokens.
        assert_eq!(
            tail_tokens(3, 0, s_p, seg, &prompt, &known),
            vec![14, 15, 16, 17]
        );
        assert_eq!(resume_point(9, seg), 8);
        assert_eq!(resume_point(8, seg), 8);
        assert_eq!(resume_point(3, seg), 0);
    }

    fn lane_worker(seed: u64) -> Server {
        let cfg = crate::model::tests::test_config();
        let params = Params::random(&cfg, seed);
        let engine =
            InferenceEngine::new(NativeBackend::new(cfg, params), ExecMode::Diagonal);
        Server::start(engine, "127.0.0.1:0", 8).unwrap()
    }

    fn shard_worker(seed: u64) -> Server {
        let cfg = ModelConfig::synthetic();
        let params = Params::random(&cfg, seed);
        let engine = InferenceEngine::new(
            NativeBackend::new(cfg.clone(), params.clone()),
            ExecMode::Diagonal,
        );
        let backend: Box<dyn StepBackend + Send> =
            Box::new(NativeBackend::new(cfg, params));
        Server::start_with(
            engine,
            "127.0.0.1:0",
            8,
            ServerOptions { shard_backend: Some(backend), fault: None },
        )
        .unwrap()
    }

    /// Collect a full stream as rendered frames, with the `done`
    /// frame's nondeterministic latency field removed.
    fn streamed(addr: &str, frame: &Value) -> (Vec<String>, Value) {
        let mut client = Client::connect(addr).unwrap();
        let mut events = Vec::new();
        let done = client
            .request_stream(frame, |ev| events.push(ev.to_json()))
            .unwrap();
        let mut m = frame_map(&done);
        m.remove("latency_ms");
        (events, Value::Obj(m))
    }

    #[test]
    fn lane_stream_is_bit_identical_to_direct_worker() {
        let w1 = lane_worker(21);
        let w2 = lane_worker(21);
        let coord = ShardCoordinator::start(
            crate::model::tests::test_config(),
            &[w1.addr.to_string(), w2.addr.to_string()],
            "127.0.0.1:0",
            CoordinatorOptions::default(),
        )
        .unwrap();

        let tokens: Vec<u32> = (0..16).map(|i| (i * 5 + 1) % 60).collect();
        let frame = Value::obj(vec![
            ("id", Value::Num(7.0)),
            ("tokens", Value::arr_u32(&tokens)),
            ("max_new_tokens", Value::Num(12.0)),
        ]);
        let (direct_events, direct_done) = streamed(&w1.addr.to_string(), &frame);
        let (coord_events, coord_done) = streamed(&coord.addr.to_string(), &frame);
        // The relayed stream is frame-for-frame identical — checkpoints
        // were injected and absorbed without the client seeing them.
        assert_eq!(coord_events, direct_events);
        assert_eq!(coord_done, direct_done);

        let stats = coord.stats();
        assert_eq!(stats.shard_routed.get(), 1);
        assert!(stats.shard_handoffs.get() >= 2, "boundary checkpoints absorbed");
        assert_eq!(stats.shard_failovers.get(), 0);

        coord.stop();
        w1.stop();
        w2.stop();
    }

    #[test]
    fn pipeline_matches_single_process_oracle() {
        let cfg = ModelConfig::synthetic();
        let w1 = shard_worker(9);
        let w2 = shard_worker(9);
        let coord = ShardCoordinator::start(
            cfg.clone(),
            &[w1.addr.to_string(), w2.addr.to_string()],
            "127.0.0.1:0",
            CoordinatorOptions { layer_split: 2, ..CoordinatorOptions::default() },
        )
        .unwrap();

        for (max_new, temperature, seed) in [(10, 0.0f32, 0u64), (10, 0.8, 7)] {
            let mut oracle =
                InferenceEngine::new(NativeBackend::new(cfg.clone(), Params::random(&cfg, 9)), ExecMode::Sequential);
            let tokens: Vec<u32> = (0..2 * cfg.seg as u32).map(|i| (i * 3 + 2) % 64).collect();
            let mut req = GenerateRequest::new(5, tokens.clone()).generate(max_new);
            req.sampling.temperature = temperature;
            req.sampling.seed = seed;
            let want = oracle.process(&req).unwrap();

            let frame = Value::obj(vec![
                ("tokens", Value::arr_u32(&tokens)),
                ("max_new_tokens", Value::Num(max_new as f64)),
                ("temperature", Value::Num(temperature as f64)),
                ("seed", Value::Num(seed as f64)),
            ]);
            let (_events, done) = streamed(&coord.addr.to_string(), &frame);
            assert_eq!(
                done.req("generated").unwrap().as_u32_vec().unwrap(),
                want.generated,
                "temperature {temperature}"
            );
            let tail: Vec<usize> = done
                .req("greedy_tail")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect();
            assert_eq!(tail, want.greedy_tail);
            assert_eq!(done.req("mode").unwrap().as_str().unwrap(), "sequential");
        }
        let stats = coord.stats();
        assert!(stats.shard_handoffs.get() > 0);
        assert!(stats.shard_handoff_bytes.get() > 0);

        coord.stop();
        w1.stop();
        w2.stop();
    }

    #[test]
    fn admin_cmds_and_attach() {
        let w1 = lane_worker(3);
        let coord = ShardCoordinator::start(
            crate::model::tests::test_config(),
            &[w1.addr.to_string()],
            "127.0.0.1:0",
            CoordinatorOptions::default(),
        )
        .unwrap();
        let mut client = Client::connect(&coord.addr.to_string()).unwrap();
        assert!(client.ping().unwrap());

        let ws = client
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("shard_workers".into()))]))
            .unwrap();
        assert_eq!(ws.req("workers").unwrap().as_arr().unwrap().len(), 1);

        let w2 = lane_worker(3);
        let reply = client
            .roundtrip(&Value::obj(vec![
                ("cmd", Value::Str("shard_attach".into())),
                ("addr", Value::Str(w2.addr.to_string())),
            ]))
            .unwrap();
        assert!(reply.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(reply.req("workers").unwrap().as_usize().unwrap(), 2);
        assert_eq!(coord.stats().shard_workers.get(), 2);

        // Unknown-id cancel mirrors the server's found=false reply.
        assert!(!client.cancel(999).unwrap());
        let stats = client
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.req("shard_workers").unwrap().as_usize().unwrap(), 2);

        coord.stop();
        w1.stop();
        w2.stop();
    }

    #[test]
    fn save_and_resume_roundtrip_through_coordinator() {
        let w1 = lane_worker(5);
        let coord = ShardCoordinator::start(
            crate::model::tests::test_config(),
            &[w1.addr.to_string()],
            "127.0.0.1:0",
            CoordinatorOptions::default(),
        )
        .unwrap();
        let addr = coord.addr.to_string();
        let tokens: Vec<u32> = (0..24).map(|i| (i * 7 + 3) % 60).collect();
        let frame = Value::obj(vec![
            ("tokens", Value::arr_u32(&tokens)),
            ("save", Value::Bool(true)),
        ]);
        let (_ev, done) = streamed(&addr, &frame);
        let token = done.req("resume_token").unwrap().as_u64().unwrap();

        let more: Vec<u32> = (0..8).map(|i| i + 2).collect();
        let resume = Value::obj(vec![
            ("tokens", Value::arr_u32(&more)),
            ("resume", Value::Num(token as f64)),
        ]);
        let (_ev, done2) = streamed(&addr, &resume);
        assert_eq!(done2.req("reused_segments").unwrap().as_usize().unwrap(), 3);

        // An unknown token errors cleanly.
        let mut client = Client::connect(&addr).unwrap();
        let bad = Value::obj(vec![
            ("tokens", Value::arr_u32(&more)),
            ("resume", Value::Num(token as f64 + 50.0)),
        ]);
        let err = client.request_stream(&bad, |_| {}).unwrap_err();
        assert!(err.to_string().contains("resume token"), "{err}");

        coord.stop();
        w1.stop();
    }
}
