//! Worker-side layer-range service: the `shard_*` command handler.
//!
//! A worker hosts *range lanes*: per-`sid` recurrent state for a
//! contiguous layer range `[lo, hi)`. The coordinator drives one
//! `shard_segment` call per (segment, range) — the worker runs
//! `embed` (first range only) + `single_step` over its layers +
//! `lm_head` (last range only) and returns the activations or logits
//! plus its post-segment range state. This is exactly the sequential
//! oracle's per-segment recurrence, split at range boundaries; the
//! existing schedule-invariance properties (P4/P7/P10) are what make
//! it bit-identical to the wavefront.
//!
//! The service is a pure `(cmd, json) -> json` function behind a
//! mutex, so tests drive it in-process and the server exposes it over
//! TCP unchanged.

use std::collections::HashMap;

use crate::cache::MemSnapshot;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::scheduler::StepBackend;
use crate::tensor::Tensor;
use crate::trace::{self, TID_CONTROL};

/// Serialize a float slice as raw `u32` bit patterns (the same
/// bit-exact convention as [`MemSnapshot::to_json`]).
pub(crate) fn bits_value(data: &[f32]) -> Value {
    Value::Arr(data.iter().map(|f| Value::Num(f.to_bits() as f64)).collect())
}

/// Inverse of [`bits_value`].
pub(crate) fn floats_from_bits(v: &Value) -> Result<Vec<f32>> {
    v.as_arr()?
        .iter()
        .map(|b| {
            let bits = b.as_u64()?;
            let bits = u32::try_from(bits)
                .map_err(|_| Error::Json(format!("f32 bit pattern {bits} > u32")))?;
            Ok(f32::from_bits(bits))
        })
        .collect()
}

/// One request's recurrent state for layers `[lo, hi)`.
struct RangeLane {
    lo: usize,
    hi: usize,
    /// Segments consumed by this lane so far.
    segments: usize,
    /// Per-layer `A [d, p]`, indexed by `layer - lo`.
    a: Vec<Tensor>,
    /// Per-layer `z [p]`, indexed by `layer - lo`.
    z: Vec<Tensor>,
}

/// The worker-side shard command handler ([`ServerOptions::shard_backend`]).
///
/// [`ServerOptions::shard_backend`]: crate::server::ServerOptions
pub struct ShardService {
    backend: Box<dyn StepBackend + Send>,
    lanes: HashMap<u64, RangeLane>,
}

impl ShardService {
    pub fn new(backend: Box<dyn StepBackend + Send>) -> Self {
        Self { backend, lanes: HashMap::new() }
    }

    /// Dispatch one `shard_*` command. Every reply is a single JSON
    /// object; errors surface as `Err` (the server renders the error
    /// frame).
    pub fn handle(&mut self, cmd: &str, v: &Value) -> Result<Value> {
        let sid = v.req("sid")?.as_u64()?;
        match cmd {
            "shard_init" => {
                let (lo, hi) = self.parse_range(v)?;
                let cfg = self.backend.config();
                let n = hi - lo;
                let lane = RangeLane {
                    lo,
                    hi,
                    segments: 0,
                    a: (0..n).map(|_| Tensor::zeros(&[cfg.d_model, cfg.phi_dim])).collect(),
                    z: (0..n).map(|_| Tensor::zeros(&[cfg.phi_dim])).collect(),
                };
                self.lanes.insert(sid, lane);
                Ok(ok_reply(sid))
            }
            "shard_load" => {
                let (lo, hi) = self.parse_range(v)?;
                let state = MemSnapshot::from_json(v.req("state")?)?;
                let cfg = self.backend.config();
                if state.model != cfg.name
                    || state.n_layers != hi - lo
                    || state.d_model != cfg.d_model
                    || state.phi_dim != cfg.phi_dim
                    || state.seg != cfg.seg
                {
                    return Err(Error::Config(format!(
                        "shard_load state (model '{}', {} layers) does not fit \
                         range [{lo}, {hi}) of model '{}'",
                        state.model, state.n_layers, cfg.name
                    )));
                }
                let lane =
                    RangeLane { lo, hi, segments: state.segments, a: state.a, z: state.z };
                self.lanes.insert(sid, lane);
                Ok(ok_reply(sid))
            }
            "shard_segment" => self.segment(sid, v),
            "shard_state" => {
                let lane = self.lane(sid)?;
                let state = range_snapshot(self.backend.config(), lane);
                Ok(Value::obj(vec![
                    ("sid", Value::Num(sid as f64)),
                    ("segments", Value::Num(state.segments as f64)),
                    ("state", state.to_json()),
                ]))
            }
            "shard_drop" => {
                let found = self.lanes.remove(&sid).is_some();
                Ok(Value::obj(vec![
                    ("ok", Value::Bool(found)),
                    ("sid", Value::Num(sid as f64)),
                ]))
            }
            other => Err(Error::Request(format!("unknown shard cmd '{other}'"))),
        }
    }

    /// One (segment, range) step: input tokens (first range) or
    /// activations, output activations (inner ranges) or logits (last
    /// range), always with the post-segment range state.
    fn segment(&mut self, sid: u64, v: &Value) -> Result<Value> {
        let lane = self
            .lanes
            .get(&sid)
            .ok_or_else(|| Error::Request(format!("unknown shard lane {sid}")))?;
        let (lo, hi) = (lane.lo, lane.hi);
        let cfg = self.backend.config();
        let (seg, n_layers) = (cfg.seg, cfg.n_layers);
        let span_start = if trace::enabled() { trace::now_us() } else { 0 };

        let mut x = if let Some(t) = v.get("tokens") {
            if lo != 0 {
                return Err(Error::Request(format!(
                    "tokens are embedded by the first range only (lane {sid} starts at \
                     layer {lo})"
                )));
            }
            let tokens = t.as_u32_vec()?;
            if tokens.len() != seg {
                return Err(Error::Request(format!(
                    "shard_segment wants exactly {seg} tokens (a padded segment), got {}",
                    tokens.len()
                )));
            }
            self.backend.embed(&tokens)?
        } else {
            let shape = v
                .req("x_shape")?
                .as_arr()?
                .iter()
                .map(Value::as_usize)
                .collect::<Result<Vec<usize>>>()?;
            Tensor::new(&shape, floats_from_bits(v.req("x_bits")?)?)?
        };

        let lane = self.lanes.get_mut(&sid).expect("checked above");
        for l in lo..hi {
            let i = l - lo;
            let (y, a2, z2) = self.backend.single_step(l, &x, &lane.a[i], &lane.z[i])?;
            x = y;
            lane.a[i] = a2;
            lane.z[i] = z2;
        }
        lane.segments += 1;

        let mut fields = vec![
            ("sid", Value::Num(sid as f64)),
            ("segments", Value::Num(lane.segments as f64)),
        ];
        if hi == n_layers {
            let logits = self.backend.lm_head(&x)?;
            fields.push(("logits_bits", bits_value(logits.data())));
        } else {
            fields.push(("x_bits", bits_value(x.data())));
            fields.push(("x_shape", Value::arr_usize(x.shape())));
        }
        let lane = self.lanes.get(&sid).expect("still present");
        fields.push(("state", range_snapshot(self.backend.config(), lane).to_json()));
        if span_start != 0 {
            trace::complete(
                "shard_segment",
                span_start,
                TID_CONTROL,
                vec![
                    ("sid", Value::Num(sid as f64)),
                    ("layer_lo", Value::Num(lo as f64)),
                    ("layer_hi", Value::Num(hi as f64)),
                    ("segments", Value::Num(lane.segments as f64)),
                ],
            );
        }
        Ok(Value::obj(fields))
    }

    fn lane(&self, sid: u64) -> Result<&RangeLane> {
        self.lanes
            .get(&sid)
            .ok_or_else(|| Error::Request(format!("unknown shard lane {sid}")))
    }

    fn parse_range(&self, v: &Value) -> Result<(usize, usize)> {
        let lo = v.req("lo")?.as_usize()?;
        let hi = v.req("hi")?.as_usize()?;
        let n = self.backend.config().n_layers;
        if lo >= hi || hi > n {
            return Err(Error::Config(format!(
                "layer range [{lo}, {hi}) invalid for a {n}-layer model"
            )));
        }
        Ok((lo, hi))
    }
}

/// A lane's state as a snapshot with `n_layers = hi - lo` — the range
/// slice convention the coordinator stitches full checkpoints from.
fn range_snapshot(cfg: &crate::config::ModelConfig, lane: &RangeLane) -> MemSnapshot {
    MemSnapshot {
        model: cfg.name.clone(),
        n_layers: lane.hi - lane.lo,
        d_model: cfg.d_model,
        phi_dim: cfg.phi_dim,
        seg: cfg.seg,
        segments: lane.segments,
        a: lane.a.clone(),
        z: lane.z.clone(),
    }
}

fn ok_reply(sid: u64) -> Value {
    Value::obj(vec![("ok", Value::Bool(true)), ("sid", Value::Num(sid as f64))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::{NativeBackend, Params};
    use crate::scheduler::segment_tokens;

    fn backend(seed: u64) -> Box<dyn StepBackend + Send> {
        let cfg = ModelConfig::synthetic();
        Box::new(NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)))
    }

    fn seg_cmd(sid: u64, tokens: &[u32]) -> Value {
        Value::obj(vec![
            ("sid", Value::Num(sid as f64)),
            ("tokens", Value::arr_u32(tokens)),
        ])
    }

    /// The in-process sequential oracle: embed -> single_step chain ->
    /// lm_head, per segment.
    fn oracle_logits(seed: u64, segments: &[Vec<u32>]) -> Vec<Vec<u32>> {
        let cfg = ModelConfig::synthetic();
        let mut b = backend(seed);
        let mut a: Vec<Tensor> =
            (0..cfg.n_layers).map(|_| Tensor::zeros(&[cfg.d_model, cfg.phi_dim])).collect();
        let mut z: Vec<Tensor> =
            (0..cfg.n_layers).map(|_| Tensor::zeros(&[cfg.phi_dim])).collect();
        let mut out = Vec::new();
        for seg in segments {
            let mut x = b.embed(seg).unwrap();
            for l in 0..cfg.n_layers {
                let (y, a2, z2) = b.single_step(l, &x, &a[l], &z[l]).unwrap();
                x = y;
                a[l] = a2;
                z[l] = z2;
            }
            let logits = b.lm_head(&x).unwrap();
            out.push(logits.data().iter().map(|f| f.to_bits()).collect());
        }
        out
    }

    fn range_init(svc: &mut ShardService, sid: u64, lo: usize, hi: usize) {
        let cmd = Value::obj(vec![
            ("sid", Value::Num(sid as f64)),
            ("lo", Value::Num(lo as f64)),
            ("hi", Value::Num(hi as f64)),
        ]);
        assert!(svc.handle("shard_init", &cmd).unwrap().req("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn full_range_matches_sequential_oracle_bitwise() {
        let cfg = ModelConfig::synthetic();
        let tokens: Vec<u32> = (0..3 * cfg.seg as u32).map(|i| (i * 7 + 3) % 64).collect();
        let segments = segment_tokens(&cfg, &tokens).unwrap();
        let want = oracle_logits(5, &segments);

        let mut svc = ShardService::new(backend(5));
        range_init(&mut svc, 1, 0, cfg.n_layers);
        for (i, seg) in segments.iter().enumerate() {
            let reply = svc.handle("shard_segment", &seg_cmd(1, seg)).unwrap();
            assert_eq!(reply.req("segments").unwrap().as_usize().unwrap(), i + 1);
            let got: Vec<u32> = floats_from_bits(reply.req("logits_bits").unwrap())
                .unwrap()
                .iter()
                .map(|f| f.to_bits())
                .collect();
            assert_eq!(got, want[i], "segment {i} logits diverge");
        }
    }

    #[test]
    fn two_range_pipeline_matches_oracle_bitwise() {
        let cfg = ModelConfig::synthetic();
        let tokens: Vec<u32> = (0..2 * cfg.seg as u32).map(|i| (i * 11 + 1) % 64).collect();
        let segments = segment_tokens(&cfg, &tokens).unwrap();
        let want = oracle_logits(9, &segments);
        let split = cfg.n_layers / 2 + 1; // uneven on purpose

        // Two services = two worker processes sharing the weights.
        let mut first = ShardService::new(backend(9));
        let mut last = ShardService::new(backend(9));
        range_init(&mut first, 7, 0, split);
        range_init(&mut last, 7, split, cfg.n_layers);

        for (i, seg) in segments.iter().enumerate() {
            let mid = first.handle("shard_segment", &seg_cmd(7, seg)).unwrap();
            // The inner range hands off activations, never logits.
            assert!(mid.get("logits_bits").is_none());
            let hand_off = Value::obj(vec![
                ("sid", Value::Num(7.0)),
                ("x_bits", mid.req("x_bits").unwrap().clone()),
                ("x_shape", mid.req("x_shape").unwrap().clone()),
            ]);
            let reply = last.handle("shard_segment", &hand_off).unwrap();
            let got: Vec<u32> = floats_from_bits(reply.req("logits_bits").unwrap())
                .unwrap()
                .iter()
                .map(|f| f.to_bits())
                .collect();
            assert_eq!(got, want[i], "segment {i} logits diverge across the pipeline");
        }
    }

    #[test]
    fn state_roundtrips_through_load() {
        let cfg = ModelConfig::synthetic();
        let seg: Vec<u32> = (0..cfg.seg as u32).collect();
        let mut svc = ShardService::new(backend(3));
        range_init(&mut svc, 1, 0, cfg.n_layers);
        let reply = svc.handle("shard_segment", &seg_cmd(1, &seg)).unwrap();
        let state = reply.req("state").unwrap().clone();

        // Load the captured state into a fresh lane on a fresh service:
        // the next segment must continue bit-identically.
        let mut fresh = ShardService::new(backend(3));
        let load = Value::obj(vec![
            ("sid", Value::Num(2.0)),
            ("lo", Value::Num(0.0)),
            ("hi", Value::Num(cfg.n_layers as f64)),
            ("state", state),
        ]);
        assert!(fresh.handle("shard_load", &load).unwrap().req("ok").unwrap().as_bool().unwrap());
        let seg2: Vec<u32> = (0..cfg.seg as u32).map(|i| i + 8).collect();
        let a = svc.handle("shard_segment", &seg_cmd(1, &seg2)).unwrap();
        let b = fresh.handle("shard_segment", &seg_cmd(2, &seg2)).unwrap();
        assert_eq!(
            a.req("logits_bits").unwrap().to_json(),
            b.req("logits_bits").unwrap().to_json()
        );
        assert_eq!(b.req("segments").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn bad_inputs_are_refused() {
        let cfg = ModelConfig::synthetic();
        let mut svc = ShardService::new(backend(1));
        let seg: Vec<u32> = (0..cfg.seg as u32).collect();
        // Unknown lane.
        assert!(svc.handle("shard_segment", &seg_cmd(9, &seg)).is_err());
        // Bad ranges.
        for (lo, hi) in [(2, 2), (3, 1), (0, cfg.n_layers + 1)] {
            let cmd = Value::obj(vec![
                ("sid", Value::Num(1.0)),
                ("lo", Value::Num(lo as f64)),
                ("hi", Value::Num(hi as f64)),
            ]);
            assert!(svc.handle("shard_init", &cmd).is_err(), "range [{lo}, {hi})");
        }
        // Tokens into a non-first range.
        range_init(&mut svc, 1, 1, cfg.n_layers);
        assert!(svc.handle("shard_segment", &seg_cmd(1, &seg)).is_err());
        // Wrong token count.
        range_init(&mut svc, 2, 0, cfg.n_layers);
        assert!(svc.handle("shard_segment", &seg_cmd(2, &seg[..2])).is_err());
        // Mismatched shard_load state.
        let mut other = ShardService::new(backend(1));
        range_init(&mut other, 3, 0, 1);
        let one_layer =
            other.handle("shard_state", &Value::obj(vec![("sid", Value::Num(3.0))])).unwrap();
        let load = Value::obj(vec![
            ("sid", Value::Num(4.0)),
            ("lo", Value::Num(0.0)),
            ("hi", Value::Num(cfg.n_layers as f64)),
            ("state", one_layer.req("state").unwrap().clone()),
        ]);
        assert!(svc.handle("shard_load", &load).is_err(), "1-layer state into a full range");
        // Unknown subcommand.
        assert!(svc.handle("shard_warp", &seg_cmd(1, &seg)).is_err());
    }
}
