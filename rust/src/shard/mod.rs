//! Sharded wavefront serving: one coordinator, N worker processes.
//!
//! Diagonal batching makes every `(segment, layer)` cell independent
//! within a wavefront step; this module promotes that independence to
//! *process* granularity. Because ARMT's per-layer recurrent state is
//! constant-size (`A [d, p]` + `z [p]` per layer — kilobytes, not a
//! paged KV cache), a lane's complete inference state crosses a socket
//! as one bit-exact [`MemSnapshot`](crate::cache::MemSnapshot) JSON
//! frame, which makes both sharding axes and failover cheap:
//!
//! * **Lane sharding** (request parallelism): the coordinator routes
//!   each admitted request to a worker over the ordinary line protocol
//!   and merges the event stream back to the client. Requests are
//!   forwarded with `"checkpoint": true`, so every segment boundary
//!   streams a `snapshot` frame the coordinator holds as a failover
//!   checkpoint (never forwarded to the client).
//! * **Layer-range sharding** (pipeline parallelism): contiguous layer
//!   ranges `[lo, hi)` per worker ([`ShardPlan`]); the coordinator
//!   drives one `shard_segment` call per (segment, range), handing the
//!   activations `x [T, d]` and receiving each range's post-segment
//!   state. Sampling runs in the coordinator via the engine's own
//!   decode state machine, so the pipeline is the sequential oracle
//!   executed across processes — bit-identical by construction.
//! * **Failover**: when a worker dies mid-request (EOF / connection
//!   error before a terminal frame), the coordinator re-admits the
//!   request on a survivor, seeding it from the latest checkpoint via
//!   `"resume_state"` (greedy decode) or replaying it from segment 0
//!   with duplicate suppression (seeded sampling, whose RNG state is
//!   not part of the snapshot). Either way the merged client stream is
//!   byte-identical to an uninterrupted run.
//!
//! [`FaultPlan`] is the test hook that makes the failover paths
//! provable: a worker can be told to die, stall, or sever a connection
//! after K protocol frames (`rust/tests/shard_failover.rs`).

mod coordinator;
mod fault;
mod plan;
mod worker;

pub use coordinator::{CoordinatorOptions, ShardCoordinator};
pub use fault::{FaultPlan, FaultState};
pub use plan::ShardPlan;
pub use worker::ShardService;
