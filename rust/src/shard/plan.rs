//! How N workers split the model: contiguous layer ranges × chains.

use crate::error::{Error, Result};

/// A sharding of the `[L, B]` wavefront across workers. The layer axis
/// splits into `ranges.len()` contiguous `[lo, hi)` ranges; workers
/// group into *chains*, each chain hosting every range once. One chain
/// serves one request end to end (`layer_split == 1` degenerates to
/// pure lane sharding: every chain is a single worker running whole
/// requests).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    pub n_layers: usize,
    /// Contiguous `[lo, hi)` layer ranges, covering `0..n_layers` in
    /// order.
    pub ranges: Vec<(usize, usize)>,
    /// `chains[c][r]` = index (into the worker list) of the worker
    /// serving `ranges[r]` for chain `c`.
    pub chains: Vec<Vec<usize>>,
}

impl ShardPlan {
    /// Split `n_layers` across `n_workers` workers in chains of
    /// `layer_split` ranges. `n_workers` must be a multiple of
    /// `layer_split` (every chain needs a full set of ranges).
    pub fn new(n_workers: usize, n_layers: usize, layer_split: usize) -> Result<Self> {
        if n_workers == 0 {
            return Err(Error::Config("shard plan needs at least one worker".into()));
        }
        if layer_split == 0 || layer_split > n_layers {
            return Err(Error::Config(format!(
                "layer split {layer_split} must be in 1..={n_layers} (the layer count)"
            )));
        }
        if n_workers % layer_split != 0 {
            return Err(Error::Config(format!(
                "{n_workers} workers cannot form chains of {layer_split} layer ranges"
            )));
        }
        let ranges = split_layers(n_layers, layer_split);
        let chains = (0..n_workers / layer_split)
            .map(|c| (0..layer_split).map(|r| c * layer_split + r).collect())
            .collect();
        Ok(Self { n_layers, ranges, chains })
    }

    /// Whole-model ranges: requests route to one worker each.
    pub fn lane_mode(&self) -> bool {
        self.ranges.len() == 1
    }
}

/// Ceil-split `n_layers` into `k` contiguous ranges — sizes differ by
/// at most one, earlier ranges take the remainder.
pub fn split_layers(n_layers: usize, k: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let hi = lo + (n_layers - lo).div_ceil(k - i);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_contiguously() {
        for n_layers in 1..=12 {
            for k in 1..=n_layers {
                let ranges = split_layers(n_layers, k);
                assert_eq!(ranges.len(), k);
                assert_eq!(ranges[0].0, 0);
                assert_eq!(ranges[k - 1].1, n_layers);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
                }
                let sizes: Vec<usize> = ranges.iter().map(|(lo, hi)| hi - lo).collect();
                let (min, max) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "unbalanced split {sizes:?}");
                assert!(*min >= 1);
            }
        }
    }

    #[test]
    fn plan_chains_partition_workers() {
        let p = ShardPlan::new(6, 4, 2).unwrap();
        assert_eq!(p.ranges, vec![(0, 2), (2, 4)]);
        assert_eq!(p.chains, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
        assert!(!p.lane_mode());

        let lanes = ShardPlan::new(3, 4, 1).unwrap();
        assert_eq!(lanes.ranges, vec![(0, 4)]);
        assert_eq!(lanes.chains, vec![vec![0], vec![1], vec![2]]);
        assert!(lanes.lane_mode());
    }

    #[test]
    fn plan_rejects_bad_shapes() {
        assert!(ShardPlan::new(0, 4, 1).is_err());
        assert!(ShardPlan::new(2, 4, 0).is_err());
        assert!(ShardPlan::new(2, 4, 5).is_err(), "more ranges than layers");
        assert!(ShardPlan::new(3, 4, 2).is_err(), "3 workers, chains of 2");
    }
}
