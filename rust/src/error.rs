//! Crate-wide error type.
//!
//! Hand-rolled (no `thiserror`) to keep the dependency closure small; the
//! binaries wrap everything in `eyre` for reporting.

use std::fmt;

/// All failure modes of the library surface.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (artifact files, params.bin, sockets).
    Io(std::io::Error),
    /// Manifest / request JSON problems (in-tree parser, `crate::json`).
    Json(String),
    /// PJRT / XLA failures from the `xla` crate.
    Xla(String),
    /// Shape or dtype mismatch between caller and artifact contract.
    Shape {
        what: &'static str,
        expected: Vec<usize>,
        got: Vec<usize>,
    },
    /// Unknown model / executable / parameter name.
    Missing(String),
    /// Configuration rejected (inconsistent dims, bad mode string, ...).
    Config(String),
    /// Scheduler invariant violated (a bug, surfaced loudly).
    Schedule(String),
    /// Request-level failure (empty input, over limit, queue closed).
    Request(String),
    /// Benchmark harness failure: a suite's expected-invariant check did
    /// not hold (the paper-shape assertions), or a report/baseline could
    /// not be read or compared.
    Bench(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Best-effort duplicate, for fan-out paths that both return an
    /// error and emit it on an event stream (`std::io::Error` is not
    /// `Clone`, so `Io` degrades to a `Request` carrying its message).
    pub fn duplicate(&self) -> Error {
        match self {
            Error::Io(e) => Error::Request(format!("io: {e}")),
            Error::Json(s) => Error::Json(s.clone()),
            Error::Xla(s) => Error::Xla(s.clone()),
            Error::Shape { what, expected, got } => {
                Error::Shape { what, expected: expected.clone(), got: got.clone() }
            }
            Error::Missing(s) => Error::Missing(s.clone()),
            Error::Config(s) => Error::Config(s.clone()),
            Error::Schedule(s) => Error::Schedule(s.clone()),
            Error::Request(s) => Error::Request(s.clone()),
            Error::Bench(s) => Error::Bench(s.clone()),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(e) => write!(f, "json: {e}"),
            Error::Xla(e) => write!(f, "xla/pjrt: {e}"),
            Error::Shape { what, expected, got } => {
                write!(f, "shape mismatch in {what}: expected {expected:?}, got {got:?}")
            }
            Error::Missing(name) => write!(f, "missing: {name}"),
            Error::Config(msg) => write!(f, "config: {msg}"),
            Error::Schedule(msg) => write!(f, "schedule invariant violated: {msg}"),
            Error::Request(msg) => write!(f, "request: {msg}"),
            Error::Bench(msg) => write!(f, "bench: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        let e = Error::Shape { what: "x", expected: vec![1, 2], got: vec![2, 1] };
        assert!(e.to_string().contains("shape mismatch"));
        assert!(Error::Missing("foo".into()).to_string().contains("foo"));
        assert!(Error::Schedule("bad".into()).to_string().contains("invariant"));
    }

    #[test]
    fn from_io() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "x").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
