//! Token sampling for the decode phase.
//!
//! The default is greedy (argmax) decoding — temperature `0.0` — which
//! is fully deterministic and is what the bit-exactness acceptance
//! tests pin down: the greedy continuation must match the sequential
//! single-shot oracle byte for byte. Temperature/top-k sampling is
//! available for serving; it is seeded per request so a given
//! `(request, seed)` pair reproduces across runs and machines.

use crate::error::{Error, Result};
use crate::tensor::{Rng, Tensor};

/// Per-request sampling configuration (part of
/// [`GenerateRequest`](crate::coordinator::GenerateRequest)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// `0.0` = greedy argmax (the deterministic default); `> 0.0`
    /// samples from `softmax(logits / temperature)`.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest-logit tokens
    /// (`0` = no restriction). Ignored under greedy decoding.
    pub top_k: usize,
    /// PRNG seed for this request's sampler. Ignored under greedy.
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

impl SamplingParams {
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    pub fn validate(&self) -> Result<()> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(Error::Request(format!(
                "temperature must be a finite non-negative number, got {}",
                self.temperature
            )));
        }
        Ok(())
    }
}

/// Stateful per-request sampler: maps one exited segment's logits
/// `[seg, vocab]` to the next segment's tokens.
#[derive(Clone, Debug)]
pub(crate) struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Self {
        let rng = Rng::new(params.seed);
        Self { params, rng }
    }

    pub fn is_greedy(&self) -> bool {
        self.params.is_greedy()
    }

    /// One next-segment prediction: position `p` of the result is drawn
    /// from row `p` of `logits`.
    pub fn next_segment(&mut self, logits: &Tensor) -> Vec<u32> {
        if self.params.is_greedy() {
            return logits.argmax_rows().iter().map(|&t| t as u32).collect();
        }
        let vocab = logits.shape()[1];
        let rows = logits.shape()[0];
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            out.push(self.sample_row(&logits.data()[r * vocab..(r + 1) * vocab]));
        }
        out
    }

    fn sample_row(&mut self, row: &[f32]) -> u32 {
        // Top-k filter (k = 0 => full vocabulary). The CDF walk does
        // not care about ordering, so the unrestricted case needs no
        // sort at all, and k > 0 needs only an O(V) partial selection.
        let k = self.params.top_k;
        let kept: Vec<usize> = if k == 0 || k >= row.len() {
            (0..row.len()).collect()
        } else {
            let mut idx: Vec<usize> = (0..row.len()).collect();
            idx.select_nth_unstable_by(k - 1, |&a, &b| {
                row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(k);
            idx
        };

        // Numerically stable softmax: subtract the max logit BEFORE
        // dividing by the temperature, so (row[i] - m) / t is always
        // <= 0 and exp() never overflows — arbitrarily small positive
        // temperatures degenerate smoothly to greedy instead of
        // producing inf/NaN.
        let t = self.params.temperature;
        let m = kept.iter().map(|&i| row[i]).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f32> = kept.iter().map(|&i| ((row[i] - m) / t).exp()).collect();
        let total: f32 = weights.iter().sum();

        // CDF walk; the final fallback covers rounding at u ~ 1.0.
        let u = self.rng.uniform() * total;
        let mut acc = 0.0f32;
        for (w, &i) in weights.iter().zip(&kept) {
            acc += w;
            if u < acc {
                return i as u32;
            }
        }
        kept[kept.len() - 1] as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits(rows: usize, vocab: usize, salt: u64) -> Tensor {
        let mut rng = Rng::new(salt);
        Tensor::randn(&[rows, vocab], 1.0, &mut rng)
    }

    #[test]
    fn greedy_matches_argmax() {
        let l = logits(4, 16, 1);
        let mut s = Sampler::new(SamplingParams::default());
        let want: Vec<u32> = l.argmax_rows().iter().map(|&t| t as u32).collect();
        assert_eq!(s.next_segment(&l), want);
        // Greedy ignores the seed entirely.
        let mut s2 = Sampler::new(SamplingParams { seed: 99, ..SamplingParams::default() });
        assert_eq!(s2.next_segment(&l), want);
    }

    #[test]
    fn seeded_sampling_is_reproducible() {
        let l = logits(8, 32, 2);
        let p = SamplingParams { temperature: 0.8, top_k: 5, seed: 7 };
        let a = Sampler::new(p).next_segment(&l);
        let b = Sampler::new(p).next_segment(&l);
        assert_eq!(a, b);
        let c = Sampler::new(SamplingParams { seed: 8, ..p }).next_segment(&l);
        // Different seed: overwhelmingly likely to differ somewhere.
        assert!(a != c || a.len() < 4, "seed had no effect: {a:?}");
    }

    #[test]
    fn top_k_restricts_support() {
        let l = logits(16, 64, 3);
        let p = SamplingParams { temperature: 1.5, top_k: 1, seed: 0 };
        // top_k = 1 degenerates to greedy regardless of temperature.
        let want: Vec<u32> = l.argmax_rows().iter().map(|&t| t as u32).collect();
        assert_eq!(Sampler::new(p).next_segment(&l), want);
    }

    #[test]
    fn sampled_tokens_stay_in_vocab() {
        let l = logits(8, 16, 4);
        let p = SamplingParams { temperature: 2.0, top_k: 0, seed: 5 };
        for &t in &Sampler::new(p).next_segment(&l) {
            assert!((t as usize) < 16);
        }
    }

    #[test]
    fn tiny_temperature_degenerates_to_greedy_not_nan() {
        // (row[i] - max) / t stays <= 0 for any positive t, so even a
        // denormal-range temperature samples the argmax instead of
        // collapsing the CDF to NaN.
        let l = logits(6, 32, 9);
        let p = SamplingParams { temperature: 1e-40, top_k: 0, seed: 3 };
        let want: Vec<u32> = l.argmax_rows().iter().map(|&t| t as u32).collect();
        assert_eq!(Sampler::new(p).next_segment(&l), want);
    }

    #[test]
    fn validation() {
        assert!(SamplingParams::default().validate().is_ok());
        assert!(SamplingParams { temperature: -1.0, ..Default::default() }.validate().is_err());
        assert!(SamplingParams { temperature: f32::NAN, ..Default::default() }
            .validate()
            .is_err());
    }
}
