//! Bounded FIFO request queue with backpressure.
//!
//! The paper's deployment note (§1 contributions) is that diagonal
//! batching saturates the device with ONE long-context request, so the
//! serving topology is simple: a depth-limited queue feeding a single
//! executor loop. Producers get `QueueFull` instead of unbounded latency.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::error::{Error, Result};

/// Thread-safe bounded FIFO.
pub struct RequestQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> RequestQueue<T> {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking push; `Err(Request("queue full"))` applies
    /// backpressure to the caller.
    pub fn push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::Request("queue closed".into()));
        }
        if g.items.len() >= self.capacity {
            return Err(Error::Request("queue full".into()));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking pop; `None` when the queue is currently empty. Used
    /// by the continuous-batching drain loop to admit work *between*
    /// wavefront iterations without ever stalling the in-flight
    /// requests.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().items.pop_front()
    }

    /// Blocking pop; `None` once the queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close: producers fail fast, consumers drain then stop.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.push(3).is_err());
        q.pop();
        assert!(q.push(3).is_ok());
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = RequestQueue::new(4);
        assert_eq!(q.try_pop(), None);
        q.push(7).unwrap();
        assert_eq!(q.try_pop(), Some(7));
        assert_eq!(q.try_pop(), None);
        q.close();
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_none() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert!(q.push(2).is_err());
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(RequestQueue::new(8));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(x) = q2.pop() {
                got.push(x);
            }
            got
        });
        for i in 0..20 {
            while q.push(i).is_err() {
                std::thread::yield_now();
            }
        }
        q.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }
}
