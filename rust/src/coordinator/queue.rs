//! Bounded FIFO request queue with backpressure.
//!
//! The paper's deployment note (§1 contributions) is that diagonal
//! batching saturates the device with ONE long-context request, so the
//! serving topology is simple: a depth-limited queue feeding a single
//! executor loop. Producers get `QueueFull` instead of unbounded latency
//! — or block with a bound via [`RequestQueue::push_timeout`] instead
//! of spinning.
//!
//! The drain loop ([`InferenceEngine::serve_queue`]
//! (crate::coordinator::InferenceEngine::serve_queue)) consumes any
//! [`JobSource`], so this FIFO and the weighted-fair
//! [`FairScheduler`](crate::gateway::FairScheduler) are interchangeable
//! behind the same admission seam.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Anything the continuous-batching drain loop can pull jobs from: a
/// blocking pop (idle engine waiting for work) and a non-blocking pop
/// (topping up the wavefront between iterations). Implemented by the
/// FIFO [`RequestQueue`], by `Arc`s of any source, and by the gateway's
/// [`FairScheduler`](crate::gateway::FairScheduler).
pub trait JobSource<J> {
    /// Blocking pop; `None` once the source is closed AND drained.
    fn pop_job(&self) -> Option<J>;
    /// Non-blocking pop; `None` when currently empty.
    fn try_pop_job(&self) -> Option<J>;
}

impl<J> JobSource<J> for RequestQueue<J> {
    fn pop_job(&self) -> Option<J> {
        self.pop()
    }
    fn try_pop_job(&self) -> Option<J> {
        self.try_pop()
    }
}

impl<J, Q: JobSource<J>> JobSource<J> for std::sync::Arc<Q> {
    fn pop_job(&self) -> Option<J> {
        (**self).pop_job()
    }
    fn try_pop_job(&self) -> Option<J> {
        (**self).try_pop_job()
    }
}

/// Thread-safe bounded FIFO.
pub struct RequestQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> RequestQueue<T> {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking push; `Err(Request("queue full"))` applies
    /// backpressure to the caller.
    pub fn push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::Request("queue closed".into()));
        }
        if g.items.len() >= self.capacity {
            return Err(Error::Request("queue full".into()));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Bounded blocking push: wait for a slot up to `timeout` instead
    /// of busy-retrying `push`. On failure the item comes back to the
    /// caller (for re-use or an error reply) together with the reason —
    /// `"queue full"` after the timeout, `"queue closed"` immediately.
    pub fn push_timeout(
        &self,
        item: T,
        timeout: Duration,
    ) -> std::result::Result<(), (T, Error)> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err((item, Error::Request("queue closed".into())));
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err((item, Error::Request("queue full".into())));
            }
            let (guard, _res) = self.not_full.wait_timeout(g, deadline - now).unwrap();
            g = guard; // loop re-checks closed / space / deadline
        }
    }

    /// Non-blocking pop; `None` when the queue is currently empty. Used
    /// by the continuous-batching drain loop to admit work *between*
    /// wavefront iterations without ever stalling the in-flight
    /// requests.
    pub fn try_pop(&self) -> Option<T> {
        let item = self.inner.lock().unwrap().items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Blocking pop; `None` once the queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close: producers fail fast, consumers drain then stop.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.push(3).is_err());
        q.pop();
        assert!(q.push(3).is_ok());
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = RequestQueue::new(4);
        assert_eq!(q.try_pop(), None);
        q.push(7).unwrap();
        assert_eq!(q.try_pop(), Some(7));
        assert_eq!(q.try_pop(), None);
        q.close();
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_none() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert!(q.push(2).is_err());
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(RequestQueue::new(8));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(x) = q2.pop() {
                got.push(x);
            }
            got
        });
        for i in 0..20 {
            // Bounded blocking push: the consumer frees a slot and the
            // not_full condvar wakes us — no busy-spin.
            let mut item = i;
            loop {
                match q.push_timeout(item, Duration::from_millis(200)) {
                    Ok(()) => break,
                    Err((back, _)) => item = back,
                }
            }
        }
        q.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn push_timeout_waits_for_a_slot() {
        let q = Arc::new(RequestQueue::new(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let drainer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.pop()
        });
        // Blocks until the drainer frees the slot, well under 5s.
        q.push_timeout(2, Duration::from_secs(5)).unwrap();
        assert_eq!(drainer.join().unwrap(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn push_timeout_times_out_and_returns_item() {
        let q: RequestQueue<u32> = RequestQueue::new(1);
        q.push(1).unwrap();
        let t0 = Instant::now();
        let (item, err) = q.push_timeout(2, Duration::from_millis(40)).unwrap_err();
        assert_eq!(item, 2);
        assert!(err.to_string().contains("queue full"), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }

    #[test]
    fn push_timeout_wakes_on_close() {
        let q = Arc::new(RequestQueue::new(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let closer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.close();
        });
        let (item, err) = q.push_timeout(2, Duration::from_secs(30)).unwrap_err();
        assert_eq!(item, 2);
        assert!(err.to_string().contains("queue closed"), "{err}");
        closer.join().unwrap();
    }

    #[test]
    fn job_source_through_arc() {
        fn drain<J, Q: JobSource<J>>(q: &Q) -> Vec<J> {
            let mut out = Vec::new();
            while let Some(j) = q.try_pop_job() {
                out.push(j);
            }
            out
        }
        let q = Arc::new(RequestQueue::new(4));
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(drain(&q), vec![1, 2]); // Arc impl
        q.push(3).unwrap();
        assert_eq!(drain(&*q), vec![3]); // direct impl
    }
}
