//! Runtime schedule selection (paper Table 9's fallback note).
//!
//! Diagonal batching is not free: fixed-width grouped steps waste ramp
//! slots and the grouped program has higher per-launch cost, so for very
//! short requests the sequential loop can win (the paper's own Table 9
//! shows x0.52-x0.87 at 4096 tokens). The policy here decides per
//! request, either from an explicit segment threshold or from a pair of
//! measured per-step costs (calibration at startup).

/// Decision inputs captured at calibration time.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Measured (or modeled) seconds per grouped step (full width L).
    pub grouped_step_s: f64,
    /// Measured seconds per single step.
    pub single_step_s: f64,
    pub n_layers: usize,
}

impl Calibration {
    /// Predicted sequential time for `s` segments.
    pub fn predict_sequential(&self, s: usize) -> f64 {
        (s * self.n_layers) as f64 * self.single_step_s
    }

    /// Predicted diagonal time for `s` segments (fixed-width executor:
    /// every one of the S+L-1 iterations is a full grouped step).
    pub fn predict_diagonal(&self, s: usize) -> f64 {
        (s + self.n_layers - 1) as f64 * self.grouped_step_s
    }

    /// Smallest segment count where diagonal is predicted to win.
    pub fn crossover_segments(&self) -> usize {
        for s in 1..=4096 {
            if self.predict_diagonal(s) < self.predict_sequential(s) {
                return s;
            }
        }
        usize::MAX
    }
}

/// The per-request mode policy.
#[derive(Clone, Debug)]
pub enum FallbackPolicy {
    /// Always diagonal (paper's main configuration).
    AlwaysDiagonal,
    /// Diagonal iff the request has at least this many segments.
    MinSegments(usize),
    /// Threshold derived from measured step costs.
    Calibrated(Calibration),
}

impl FallbackPolicy {
    /// True if the request should run the diagonal schedule.
    pub fn use_diagonal(&self, n_segments: usize) -> bool {
        match self {
            FallbackPolicy::AlwaysDiagonal => true,
            FallbackPolicy::MinSegments(min) => n_segments >= *min,
            FallbackPolicy::Calibrated(c) => {
                c.predict_diagonal(n_segments) < c.predict_sequential(n_segments)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_segments_threshold() {
        let p = FallbackPolicy::MinSegments(4);
        assert!(!p.use_diagonal(3));
        assert!(p.use_diagonal(4));
    }

    #[test]
    fn calibrated_crossover() {
        // grouped step costs 6x a single step with L = 16: diagonal wins
        // once (s + 15) * 6 < s * 16  <=>  s > 9, i.e. from s = 10 on.
        let c = Calibration { grouped_step_s: 6.0, single_step_s: 1.0, n_layers: 16 };
        assert_eq!(c.crossover_segments(), 10);
        let p = FallbackPolicy::Calibrated(c);
        assert!(!p.use_diagonal(5));
        assert!(p.use_diagonal(16));
    }

    #[test]
    fn degenerate_calibration_never_diagonal() {
        // grouped step costs more than L single steps: never profitable.
        let c = Calibration { grouped_step_s: 20.0, single_step_s: 1.0, n_layers: 16 };
        assert_eq!(c.crossover_segments(), usize::MAX);
        assert!(!FallbackPolicy::Calibrated(c).use_diagonal(4096));
    }

    #[test]
    fn always_diagonal() {
        assert!(FallbackPolicy::AlwaysDiagonal.use_diagonal(1));
    }
}
