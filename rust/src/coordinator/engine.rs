//! The inference engine: streaming generation lifecycle over any
//! [`StepBackend`].
//!
//! The request/response surface is a *stream*: a [`GenerateRequest`]
//! (prompt + `max_new_tokens` + [`SamplingParams`] + optional deadline)
//! produces a sequence of [`Event`]s — [`Event::SegmentDone`] as each
//! prompt/decode segment exits the model, [`Event::Token`] for every
//! generated token, and a terminal [`Event::Done`] (aggregate
//! [`Response`]) or [`Event::Error`]. A [`RequestHandle`] cloned off
//! the request cancels it from any thread, mid-prefill or mid-decode.
//!
//! Two execution paths share one backend:
//!
//! * [`InferenceEngine::generate`] / [`InferenceEngine::process`] — the
//!   single-shot path: one request, any [`ExecMode`]; `process` is the
//!   collect-all-events special case (it returns only the terminal
//!   [`Response`]), which keeps it the oracle for the bit-exactness
//!   tests;
//! * [`InferenceEngine::serve_queue`] — the serving path: a continuous
//!   drain loop that packs every diagonal-mode request into one
//!   persistent [`WavefrontSession`], admitting new requests from the
//!   [`RequestQueue`] *between wavefront iterations*. Decode happens
//!   **inside the live wavefront**: when a request's prefill segments
//!   drain, its sampled continuation is appended to the same lane
//!   ([`WavefrontSession::append_segment`]), so generation from many
//!   concurrent users keeps sharing grouped launches instead of
//!   serializing — and each request's continuation stays bit-identical
//!   to a solo run (decode is just more segments of the same exact
//!   recurrence).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::{MemSnapshot, PrefixStore};
use crate::config::{ExecMode, ModelConfig};
use crate::coordinator::fallback::{Calibration, FallbackPolicy};
use crate::coordinator::queue::RequestQueue;
use crate::coordinator::sampling::{Sampler, SamplingParams};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::metrics::{Counter, Gauge, Histogram, Ratio};
use crate::quality::{self, MemoryMonitor, OverflowPolicy, SegmentSignals};
use crate::scheduler::{
    segment_tokens, RunStats, StepBackend, WavefrontSession,
};
use crate::tensor::Tensor;
use crate::trace::{self, TraceEvent, TID_CONTROL, TID_WAVEFRONT};

/// Where a request's recurrent memory starts: fresh (None on
/// [`GenerateRequest::resume`]), a conversation the engine retained
/// under an engine-assigned token, or an explicit snapshot (disk
/// round-trip).
#[derive(Clone, Debug)]
pub enum ResumeFrom {
    /// A conversation saved in the engine (`"save": true` or
    /// `{"cmd": "save", "id": N}`): the engine-assigned token echoed
    /// as `resume_token` in the terminal `done` frame. Tokens are
    /// unique per engine — a later save can never silently overwrite
    /// another conversation. The prompt carries only the NEW tokens —
    /// the saved history is never re-prefilled.
    Token(u64),
    /// An explicit [`MemSnapshot`] — what `--resume-file` loads from
    /// disk, and what embedding callers pass directly.
    Snapshot(Box<MemSnapshot>),
}

/// Cross-thread request flags, shared between a [`GenerateRequest`] and
/// every [`RequestHandle`] cloned off it.
#[derive(Debug, Default)]
struct ReqFlags {
    cancel: AtomicBool,
    /// Retain the final memory state at completion (conversation
    /// suspend). Settable mid-flight from any thread, like cancel.
    save: AtomicBool,
}

/// One generation request: prompt tokens plus the decode budget and
/// sampling configuration. `max_new_tokens = 0` is a pure prefill
/// (scoring) request — the old one-shot RPC is that special case.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub id: u64,
    /// Prompt tokens (segmented and padded internally). When
    /// [`resume`](Self::resume) is set these are only the NEW tokens —
    /// the resumed history stays frozen in the snapshot.
    pub prompt: Vec<u32>,
    /// Decode budget: how many new tokens to generate after the prompt.
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    /// Wall-clock budget measured from admission; an expired request is
    /// evicted from the wavefront with [`Event::Error`].
    pub deadline: Option<Duration>,
    /// Optional per-request mode override.
    pub mode: Option<ExecMode>,
    /// Return full logits in the terminal [`Response`] (false = only
    /// the greedy tail / generated tokens). With a prefix-cache hit or
    /// resume, logits cover only the segments actually computed.
    pub want_logits: bool,
    /// Seed the recurrence from a saved conversation or snapshot
    /// instead of empty memory.
    pub resume: Option<ResumeFrom>,
    /// Emit an [`Event::Snapshot`] at every segment boundary (prompt
    /// and decode) on the serving path — the shard coordinator's
    /// failover checkpoints. Off by default: checkpoint capture costs a
    /// state clone per boundary.
    pub checkpoint: bool,
    /// Memory-overflow handling for long contexts (wire field
    /// `overflow`, CLI `--overflow`; see the [`quality`](crate::quality)
    /// module). `Off` (the default) never consults the quality tier for
    /// control flow, so output is bit-identical to a build without it.
    pub overflow: OverflowPolicy,
    /// Trace id correlating this request's spans across processes
    /// (wire field `"trace"`, HTTP `X-Trace-Id` — see
    /// [`trace`](crate::trace)). `None` and tracing enabled: the
    /// engine assigns one at admission. A client-supplied id is echoed
    /// in the terminal `done` frame so hops stitch into one trace.
    pub trace: Option<u64>,
    /// When the request entered the serving queue (stamped by the
    /// front end at parse time); admission observes the queue-wait
    /// histogram and span from it. `None` on direct single-shot calls.
    pub enqueued: Option<Instant>,
    /// Shared with every [`RequestHandle`] cloned off this request —
    /// cancellation plus the save-on-completion flag
    /// ([`with_save`](Self::with_save) / [`RequestHandle::request_save`]).
    flags: Arc<ReqFlags>,
}

impl GenerateRequest {
    pub fn new(id: u64, prompt: Vec<u32>) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens: 0,
            sampling: SamplingParams::default(),
            deadline: None,
            mode: None,
            want_logits: false,
            resume: None,
            checkpoint: false,
            overflow: OverflowPolicy::Off,
            trace: None,
            enqueued: None,
            flags: Arc::new(ReqFlags::default()),
        }
    }

    /// Builder: set the decode budget.
    pub fn generate(mut self, max_new_tokens: usize) -> Self {
        self.max_new_tokens = max_new_tokens;
        self
    }

    /// Builder: set the sampling configuration.
    pub fn with_sampling(mut self, sampling: SamplingParams) -> Self {
        self.sampling = sampling;
        self
    }

    /// Builder: set the wall-clock deadline (measured from admission).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Builder: override the execution mode.
    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = Some(mode);
        self
    }

    /// Builder: retain the final memory state (conversation suspend) —
    /// the terminal [`Response`] then carries an engine-assigned
    /// `resume_token` plus the snapshot, and the engine keeps a copy
    /// for [`ResumeFrom::Token`]. Sets the same shared flag as
    /// [`RequestHandle::request_save`], so the intent lives in exactly
    /// one place.
    pub fn with_save(self) -> Self {
        self.flags.save.store(true, Ordering::SeqCst);
        self
    }

    pub fn save_requested(&self) -> bool {
        self.flags.save.load(Ordering::SeqCst)
    }

    /// Builder: emit [`Event::Snapshot`] boundary checkpoints on the
    /// serving path (see the field docs).
    pub fn with_checkpoint(mut self) -> Self {
        self.checkpoint = true;
        self
    }

    /// Builder: set the memory-overflow policy (`overflow: "select"` /
    /// `"chunked"` on the wire, `--overflow` on the CLI).
    pub fn with_overflow(mut self, overflow: OverflowPolicy) -> Self {
        self.overflow = overflow;
        self
    }

    /// Builder: correlate this request's spans under an existing trace
    /// id (cross-process propagation — the shard coordinator and the
    /// HTTP gateway's `X-Trace-Id` use this).
    pub fn with_trace(mut self, trace: u64) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Builder: resume a conversation the engine saved earlier
    /// (`prompt` then carries only the new tokens).
    pub fn resume_token(mut self, token: u64) -> Self {
        self.resume = Some(ResumeFrom::Token(token));
        self
    }

    /// Builder: resume from an explicit snapshot (e.g. loaded from
    /// disk via [`MemSnapshot::load`]).
    pub fn resume_snapshot(mut self, snapshot: MemSnapshot) -> Self {
        self.resume = Some(ResumeFrom::Snapshot(Box::new(snapshot)));
        self
    }

    /// A handle that can cancel this request (or flag it for save)
    /// from any thread. Clones of the request share the flags.
    pub fn handle(&self) -> RequestHandle {
        RequestHandle { id: self.id, flags: Arc::clone(&self.flags) }
    }

    pub fn is_cancelled(&self) -> bool {
        self.flags.cancel.load(Ordering::SeqCst)
    }
}

/// Per-request control handle ([`GenerateRequest::handle`]). The
/// engine polls the cancel flag between wavefront iterations; an
/// in-flight request is evicted from its lane (memory freed, other
/// requests untouched) and terminates its event stream with
/// [`Event::Error`]. The save flag marks the request for conversation
/// suspend at completion (`{"cmd": "save", "id": N}` sets it from any
/// connection, like cancel).
#[derive(Clone, Debug)]
pub struct RequestHandle {
    id: u64,
    flags: Arc<ReqFlags>,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn cancel(&self) {
        self.flags.cancel.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flags.cancel.load(Ordering::SeqCst)
    }

    /// Ask for the request's final memory state to be retained at
    /// completion (no-op if the engine did not enable capture for this
    /// request — see the serving docs).
    pub fn request_save(&self) {
        self.flags.save.store(true, Ordering::SeqCst);
    }

    pub fn save_requested(&self) -> bool {
        self.flags.save.load(Ordering::SeqCst)
    }
}

/// One element of a request's event stream.
#[derive(Debug)]
pub enum Event {
    /// Segment `index` (prompt or decode) exited the last layer;
    /// `greedy` is its per-position argmax — streamed partial results.
    /// `saturation` is the request's memory-saturation estimate after
    /// this segment's write ([`quality::MemoryMonitor`]).
    SegmentDone { index: usize, greedy: Vec<u32>, saturation: f64 },
    /// One generated token; `pos` counts new tokens from 0.
    Token { pos: usize, token: u32 },
    /// Non-terminal: the post-segment memory state of segment `index`
    /// (absolute), emitted for requests submitted with
    /// [`GenerateRequest::with_checkpoint`]. This is the shard
    /// coordinator's failover checkpoint: holding the latest one lets a
    /// dead worker's request resume on a survivor via
    /// [`ResumeFrom::Snapshot`] with zero recompute of the consumed
    /// segments.
    Snapshot { index: usize, state: Box<MemSnapshot> },
    /// Terminal: the request finished; the aggregate [`Response`].
    Done { stats: Box<Response> },
    /// Terminal: the request failed, was cancelled, or missed its
    /// deadline.
    Error { error: Error },
}

impl Event {
    /// Terminal events end a request's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Done { .. } | Event::Error { .. })
    }
}

/// Terminal aggregate of one request ([`Event::Done`]; also what
/// [`InferenceEngine::process`] returns).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Greedy (argmax) token per position of the final segment.
    pub greedy_tail: Vec<usize>,
    /// Tokens produced by the decode phase, in order
    /// (`max_new_tokens` of them on success).
    pub generated: Vec<u32>,
    /// Full per-segment logits if requested (prompt + fed decode
    /// segments). With a prefix-cache hit or resume, only the segments
    /// actually computed — the first entry is absolute segment
    /// `reused_segments`.
    pub logits: Option<Vec<Tensor>>,
    /// Prefill segments skipped via a prefix-cache hit or a resumed
    /// conversation (their memory came from a [`MemSnapshot`]).
    pub reused_segments: usize,
    /// Prompt segments whose recurrent memory write was gated by
    /// segment selection (`overflow: "select"`; attention still saw
    /// them).
    pub segments_skipped: usize,
    /// The request was re-routed to chunked windowed processing
    /// (`overflow: "chunked"` with saturation over the threshold).
    pub overflow_routed: bool,
    /// Final memory-saturation estimate in `[0, 1]`
    /// ([`quality::MemoryMonitor`]; 0.0 for full-attention runs, which
    /// have no recurrent memory).
    pub saturation: f64,
    /// Set when the conversation was saved at completion: pass as the
    /// wire field `"resume": token` (or [`GenerateRequest::resume_token`])
    /// to continue it with only new tokens. Engine-assigned and unique
    /// — never aliases another conversation.
    pub resume_token: Option<u64>,
    /// The final memory state, when saving was requested — what
    /// `--save-file` writes to disk ([`MemSnapshot::save`]).
    pub final_state: Option<MemSnapshot>,
    pub mode_used: ExecMode,
    pub stats: RunStats,
    pub latency: Duration,
    /// The client-supplied trace id, echoed verbatim (wire field
    /// `"trace"` in the `done` frame). Engine-assigned ids are NOT
    /// echoed — turning tracing on must not change output bytes for
    /// clients that did not opt in.
    pub trace: Option<u64>,
}

/// Aggregate serving counters (shared: the engine thread writes, any
/// connection thread may snapshot via [`InferenceEngine::stats_handle`]).
#[derive(Default)]
pub struct EngineStats {
    pub requests: Counter,
    pub rejected: Counter,
    /// Requests evicted by `cancel()` / client disconnect / deadline.
    pub cancelled: Counter,
    pub diagonal_runs: Counter,
    pub sequential_runs: Counter,
    pub full_attn_runs: Counter,
    /// Requests served inside a packed wavefront session (subset of
    /// `diagonal_runs`).
    pub packed_requests: Counter,
    /// Prompt tokens consumed, as submitted (unpadded; identical
    /// accounting on the single-shot and serving paths). Decode output
    /// counts separately in `generated_tokens`.
    pub tokens: Counter,
    /// Tokens produced by the decode phase.
    pub generated_tokens: Counter,
    pub latency: Histogram,
    /// Time to first generated token, measured from wavefront
    /// admission (add `queue_wait` for arrival-relative TTFT).
    pub ttft: Histogram,
    /// Gap between consecutive generated tokens within one request.
    /// Decode is segment-recurrent, so tokens arrive in per-segment
    /// bursts: intra-burst gaps are ~0, the burst boundary carries the
    /// real segment-step latency.
    pub inter_token: Histogram,
    /// Front-end enqueue to engine admission (the queue-wait stage of
    /// every request span).
    pub queue_wait: Histogram,
    /// Grouped/step launches across all runs and sessions. Wavefront
    /// schedules only — full-attention runs execute no grouped slots
    /// and stay out of the occupancy accounting entirely.
    pub launches: Counter,
    /// Wavefront occupancy: active cells / slot-steps, across all runs
    /// and sessions. The denominator-minus-numerator is the padded-cell
    /// count the ISSUE's utilization work drives down.
    pub occupancy: Ratio,
    /// Backend worker threads executing cells (1 = inline execution;
    /// set by `serve_queue` from the backend's pool).
    pub workers: Gauge,
    /// Cells the serving loop executed on pool workers (subset of
    /// `active_cells`: single-cell wavefront tips run inline).
    pub pool_cells: Counter,
    /// Worker utilization while serving: summed worker busy-time over
    /// `threads x` serving wall-time, both in microseconds. The
    /// parallel-execution analog of `occupancy` — occupancy says how
    /// full the wavefront's *slots* are, this says how busy the
    /// *threads* executing them are.
    pub worker_busy: Ratio,
    /// Prefix-cache lookups that found a reusable cached prefix.
    pub cache_hits: Counter,
    /// Prefill segments skipped thanks to prefix-cache hits — work the
    /// engine never had to execute.
    pub cache_hit_segments: Counter,
    /// Bytes currently resident in the prefix store (gauge, refreshed
    /// on every store operation).
    pub cache_bytes: Gauge,
    /// Snapshots dropped by retention limits: prefix-store entries
    /// evicted by the byte budget plus saved conversations beyond the
    /// engine's cap.
    pub cache_evictions: Counter,
    /// Floating-point operations the GEMM kernel tier retired while
    /// this engine was serving (delta-accumulated from the
    /// process-global [`tensor::kernel_totals`](crate::tensor::kernel_totals)
    /// each wavefront iteration).
    pub kernel_flops: Counter,
    /// Wall-nanoseconds the kernel tier spent retiring those flops.
    /// `kernel_flops / kernel_ns` is the achieved GFLOP/s, exactly
    /// (flops per nanosecond == 1e9 flops per second).
    pub kernel_ns: Counter,
    /// Requests the shard coordinator routed to a worker (coordinator
    /// side; zero on plain workers and single-process engines).
    pub shard_routed: Counter,
    /// Worker deaths the coordinator survived by re-admitting the
    /// in-flight request on another worker.
    pub shard_failovers: Counter,
    /// Cross-process hand-off frames: pipeline activation/state frames
    /// plus absorbed failover checkpoints.
    pub shard_handoffs: Counter,
    /// Serialized bytes those hand-off frames carried.
    pub shard_handoff_bytes: Counter,
    /// Workers the coordinator currently believes are alive.
    pub shard_workers: Gauge,
    /// Latest observed memory saturation across served requests, in
    /// thousandths (gauges are integral; the stats JSON and `/metrics`
    /// divide back into `[0, 1]`).
    pub saturation_milli: Gauge,
    /// Prompt segments whose memory write was gated by segment
    /// selection (`overflow: "select"`).
    pub segments_skipped: Counter,
    /// Requests re-routed to chunked windowed processing
    /// (`overflow: "chunked"`).
    pub overflow_routed: Counter,
}

impl EngineStats {
    /// Mean active cells per launch (the paper's utilization proxy,
    /// aggregated over everything this engine executed).
    pub fn mean_group(&self) -> f64 {
        let launches = self.launches.get();
        if launches == 0 {
            0.0
        } else {
            self.occupancy.parts().0 as f64 / launches as f64
        }
    }

    /// Padded slot-steps accumulated so far. (`Ratio` snapshots are
    /// ordered so active <= slots; saturate anyway — a stats read must
    /// never panic the serving path.)
    pub fn padded_cells(&self) -> u64 {
        let (active, slots) = self.occupancy.parts();
        slots.saturating_sub(active)
    }

    /// Achieved GFLOP/s of the kernel tier over this engine's serving
    /// windows (0.0 before any kernel work lands).
    pub fn kernel_gflops(&self) -> f64 {
        let ns = self.kernel_ns.get();
        if ns == 0 {
            0.0
        } else {
            self.kernel_flops.get() as f64 / ns as f64
        }
    }

    /// Snapshot as a JSON object (the server's `{"cmd": "stats"}` body).
    /// Derived fields are computed from ONE occupancy snapshot so they
    /// stay mutually consistent under concurrent engine writes.
    pub fn to_json(&self) -> Value {
        let (active, slots) = self.occupancy.parts();
        let launches = self.launches.get();
        let mean_group =
            if launches == 0 { 0.0 } else { active as f64 / launches as f64 };
        let occupancy = if slots == 0 { 0.0 } else { active as f64 / slots as f64 };
        Value::obj(vec![
            ("requests", Value::Num(self.requests.get() as f64)),
            ("rejected", Value::Num(self.rejected.get() as f64)),
            ("cancelled", Value::Num(self.cancelled.get() as f64)),
            ("diagonal_runs", Value::Num(self.diagonal_runs.get() as f64)),
            ("sequential_runs", Value::Num(self.sequential_runs.get() as f64)),
            ("full_attn_runs", Value::Num(self.full_attn_runs.get() as f64)),
            ("packed_requests", Value::Num(self.packed_requests.get() as f64)),
            ("tokens", Value::Num(self.tokens.get() as f64)),
            ("generated_tokens", Value::Num(self.generated_tokens.get() as f64)),
            ("launches", Value::Num(launches as f64)),
            ("active_cells", Value::Num(active as f64)),
            ("slot_steps", Value::Num(slots as f64)),
            ("padded_cells", Value::Num(slots.saturating_sub(active) as f64)),
            ("mean_group", Value::Num(mean_group)),
            ("occupancy", Value::Num(occupancy)),
            ("cache_hits", Value::Num(self.cache_hits.get() as f64)),
            ("cache_hit_segments", Value::Num(self.cache_hit_segments.get() as f64)),
            ("cache_bytes", Value::Num(self.cache_bytes.get() as f64)),
            ("evictions", Value::Num(self.cache_evictions.get() as f64)),
            ("workers", Value::Num(self.workers.get() as f64)),
            ("pool_cells", Value::Num(self.pool_cells.get() as f64)),
            ("pool_busy_ms", Value::Num(self.worker_busy.parts().0 as f64 / 1e3)),
            ("worker_utilization", Value::Num(self.worker_busy.value())),
            ("latency_ms_mean", Value::Num(self.latency.mean().as_secs_f64() * 1e3)),
            ("latency_ms_p50", Value::Num(self.latency.quantile(0.5).as_secs_f64() * 1e3)),
            ("latency_ms_p90", Value::Num(self.latency.quantile(0.9).as_secs_f64() * 1e3)),
            ("latency_ms_p99", Value::Num(self.latency.quantile(0.99).as_secs_f64() * 1e3)),
            ("ttft_ms_p50", Value::Num(self.ttft.quantile(0.5).as_secs_f64() * 1e3)),
            ("ttft_ms_p99", Value::Num(self.ttft.quantile(0.99).as_secs_f64() * 1e3)),
            ("inter_token_ms_p50", Value::Num(self.inter_token.quantile(0.5).as_secs_f64() * 1e3)),
            ("inter_token_ms_p99", Value::Num(self.inter_token.quantile(0.99).as_secs_f64() * 1e3)),
            ("queue_wait_ms_p50", Value::Num(self.queue_wait.quantile(0.5).as_secs_f64() * 1e3)),
            ("queue_wait_ms_p99", Value::Num(self.queue_wait.quantile(0.99).as_secs_f64() * 1e3)),
            ("kernel_flops", Value::Num(self.kernel_flops.get() as f64)),
            ("kernel_time_ms", Value::Num(self.kernel_ns.get() as f64 / 1e6)),
            ("kernel_gflops", Value::Num(self.kernel_gflops())),
            ("kernel_policy", Value::Str(crate::tensor::kernel_policy().to_string())),
            ("shard_routed", Value::Num(self.shard_routed.get() as f64)),
            ("shard_failovers", Value::Num(self.shard_failovers.get() as f64)),
            ("shard_handoffs", Value::Num(self.shard_handoffs.get() as f64)),
            ("shard_handoff_bytes", Value::Num(self.shard_handoff_bytes.get() as f64)),
            ("shard_workers", Value::Num(self.shard_workers.get() as f64)),
            ("saturation", Value::Num(self.saturation_milli.get() as f64 / 1e3)),
            ("segments_skipped", Value::Num(self.segments_skipped.get() as f64)),
            ("overflow_routed", Value::Num(self.overflow_routed.get() as f64)),
            // Per-kernel breakdown, process-global since process start
            // (the engine-window deltas above cover "this engine"; the
            // breakdown tells you WHICH kernels are doing the work).
            (
                "kernels",
                Value::Obj(
                    crate::tensor::kernel_snapshot()
                        .iter()
                        .map(|k| {
                            (
                                k.name.to_string(),
                                Value::obj(vec![
                                    ("calls", Value::Num(k.calls as f64)),
                                    ("flops", Value::Num(k.flops as f64)),
                                    ("time_ms", Value::Num(k.ns as f64 / 1e6)),
                                    ("gflops", Value::Num(k.gflops())),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// What the decode driver wants done with the stream after one exit.
/// `pub(crate)` so the shard coordinator's pipeline path can drive the
/// exact same state machine across processes.
pub(crate) enum ExitAction {
    /// Not the frontier segment — nothing to feed yet.
    Wait,
    /// Feed this segment back into the live wavefront
    /// ([`WavefrontSession::append_segment`]).
    Feed(Vec<u32>),
    /// Budget exhausted — close the stream
    /// ([`WavefrontSession::finish_stream`]).
    Finish,
}

/// Per-request decode state machine, shared by the single-shot and the
/// packed serving paths: turns segment exits into `SegmentDone`/`Token`
/// events and decides when to feed the sampled continuation back into
/// the stream. The scheme is segment-recurrent: the argmax/sample of
/// segment `i`'s logits IS the predicted segment `i + 1`, so one exit
/// yields up to `seg` new tokens and (budget permitting) one appended
/// segment — exactly the recurrence the sequential oracle runs.
pub(crate) struct GenDriver {
    sampler: Sampler,
    /// New tokens still to emit.
    budget_left: usize,
    /// New tokens emitted so far (the `pos` counter).
    emitted: usize,
    /// Segments fed to the stream so far (prompt + appended).
    pub(crate) fed: usize,
    pub(crate) generated: Vec<u32>,
    /// Argmax of the most recently exited segment.
    pub(crate) last_greedy: Vec<usize>,
}

impl GenDriver {
    pub(crate) fn new(req: &GenerateRequest, prompt_segments: usize) -> Self {
        Self {
            sampler: Sampler::new(req.sampling),
            budget_left: req.max_new_tokens,
            emitted: 0,
            fed: prompt_segments,
            generated: Vec::new(),
            last_greedy: Vec::new(),
        }
    }

    pub(crate) fn on_exit<F: FnMut(Event)>(
        &mut self,
        index: usize,
        logits: &Tensor,
        saturation: f64,
        emit: &mut F,
    ) -> ExitAction {
        let greedy = logits.argmax_rows();
        emit(Event::SegmentDone {
            index,
            greedy: greedy.iter().map(|&t| t as u32).collect(),
            saturation,
        });
        self.last_greedy = greedy;
        if index + 1 != self.fed {
            return ExitAction::Wait; // an earlier segment, not the frontier
        }
        if self.budget_left == 0 {
            // Pure prefill: the stream was closed at submission, so this
            // final exit already completed the request inside the
            // session — nothing to feed, nothing to close.
            return ExitAction::Wait;
        }
        // Greedy decode reuses the argmax just computed for the
        // SegmentDone event instead of re-scanning [seg, vocab].
        let next: Vec<u32> = if self.sampler.is_greedy() {
            self.last_greedy.iter().map(|&t| t as u32).collect()
        } else {
            self.sampler.next_segment(logits)
        };
        let take = self.budget_left.min(next.len());
        for (i, &t) in next[..take].iter().enumerate() {
            emit(Event::Token { pos: self.emitted + i, token: t });
        }
        self.generated.extend_from_slice(&next[..take]);
        self.emitted += take;
        self.budget_left -= take;
        if self.budget_left > 0 {
            // The full predicted segment goes back in; its own exit
            // will produce the next one.
            self.fed += 1;
            ExitAction::Feed(next)
        } else {
            ExitAction::Finish
        }
    }
}

/// Ticket held for a request packed into the serving wavefront.
struct ServeTicket<T> {
    ticket: T,
    wire_id: u64,
    /// Raw (unpadded) prompt length, for the `tokens` counter.
    prompt_tokens: usize,
    want_logits: bool,
    /// Full history segment blocks, the prefix-store insert key (None
    /// when token-resumed — the history tokens are not known).
    blocks: Option<Vec<Vec<u32>>>,
    /// Absolute prompt segment count (reused + computed).
    total_prompt: usize,
    /// Prefill segments skipped on admission (prefix hit or resume).
    reused: usize,
    pulled: Instant,
    deadline: Option<Instant>,
    handle: RequestHandle,
    driver: GenDriver,
    /// Emit boundary [`Event::Snapshot`]s (shard failover checkpoints).
    checkpoint: bool,
    /// Per-request saturation estimator (always on; observation only).
    monitor: MemoryMonitor,
    /// Absolute prompt segment indices whose memory write is gated
    /// (`overflow: "select"`).
    gated: HashSet<usize>,
    /// Admission re-routed this request to a chunked context window.
    routed: bool,
    /// Trace/latency cursors (plain POD — held even with tracing off,
    /// because the TTFT/inter-token histograms always observe).
    tr: ReqTrace,
    /// The client-supplied trace id to echo in the `done` frame
    /// (None for engine-assigned ids — see [`Response::trace`]).
    wire_trace: Option<u64>,
}

/// Per-request tracing and token-latency state.
#[derive(Default)]
struct ReqTrace {
    /// Trace id stitching this request's spans; 0 = no spans (tracing
    /// was off at admission and the client sent no id).
    id: u64,
    /// Request span start, us since the trace epoch.
    started_us: u64,
    /// End of the previous per-segment span (the next one starts here,
    /// so a lane's segment spans tile its residency without gaps).
    last_span_us: u64,
    /// Last lane this request was observed streaming on (Chrome `tid`).
    lane: u64,
    /// When the previous generated token was emitted (None until the
    /// first, whose gap is the TTFT observation).
    last_token_at: Option<Instant>,
}

/// Resolve the span trace id for a request: the client-supplied id if
/// any, a fresh engine-assigned one when tracing is on, else 0 (no
/// spans are recorded). Called once per request at admission.
fn span_trace_id(req: &GenerateRequest) -> u64 {
    match req.trace {
        Some(t) if t != 0 => t,
        _ => {
            if trace::enabled() {
                trace::next_trace_id()
            } else {
                0
            }
        }
    }
}

/// How a request's prefill will run: which segments still need
/// computing, and where their memory starts.
struct PrefillPlan {
    /// Segments to compute (the tail after any reused prefix).
    segments: Vec<Vec<u32>>,
    /// Seed state for the first computed segment (prefix hit / resume).
    snapshot: Option<MemSnapshot>,
    /// Absolute prompt segment count (reused + computed).
    total_prompt: usize,
    /// Segments whose computation was skipped.
    reused: usize,
    /// Full history block key for prefix-store inserts; None when the
    /// history tokens are unknown (token resume).
    blocks: Option<Vec<Vec<u32>>>,
}

/// Engine over any [`StepBackend`].
pub struct InferenceEngine<B: StepBackend> {
    backend: B,
    mode: ExecMode,
    policy: FallbackPolicy,
    max_request_tokens: usize,
    /// Slot lanes per wavefront session (`serve_queue`); 1 = pure
    /// stream packing, >1 additionally batches lanes per launch on
    /// backends whose grouped program is lane-batched (native). The
    /// current single-lane HLO artifacts execute extra lanes serially —
    /// correct but not faster — so leave this at 1 there.
    lanes: usize,
    /// Prefix-reuse cache (`--cache-bytes`); None = disabled, zero
    /// capture overhead.
    cache: Option<PrefixStore>,
    /// Saved conversations, keyed by engine-assigned resume token
    /// ([`ResumeFrom::Token`]). Bounded: least-recently-resumed
    /// conversations are dropped beyond [`with_max_saved`](Self::with_max_saved).
    saved: HashMap<u64, SavedConversation>,
    next_resume_token: u64,
    saved_clock: u64,
    max_saved: usize,
    pub stats: Arc<EngineStats>,
}

/// One retained conversation: its final memory state plus an LRU clock.
struct SavedConversation {
    snap: MemSnapshot,
    last_used: u64,
}

impl<B: StepBackend> InferenceEngine<B> {
    pub fn new(backend: B, mode: ExecMode) -> Self {
        Self {
            backend,
            mode,
            policy: FallbackPolicy::AlwaysDiagonal,
            max_request_tokens: 1 << 20,
            lanes: 1,
            cache: None,
            saved: HashMap::new(),
            next_resume_token: 1,
            saved_clock: 0,
            max_saved: 256,
            stats: Arc::new(EngineStats::default()),
        }
    }

    pub fn with_policy(mut self, policy: FallbackPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_max_tokens(mut self, max: usize) -> Self {
        self.max_request_tokens = max;
        self
    }

    pub fn with_lanes(mut self, lanes: usize) -> Self {
        self.lanes = lanes.max(1);
        self
    }

    /// Enable the memory-state prefix cache with an LRU byte budget
    /// (`--cache-bytes N`; 0 disables). With the cache on, every
    /// diagonal request's prompt-segment boundary states are captured
    /// and inserted into the [`PrefixStore`], and admissions look up
    /// the longest cached prefix to skip its prefill entirely.
    pub fn with_cache_bytes(mut self, bytes: usize) -> Self {
        self.cache = (bytes > 0).then(|| PrefixStore::new(bytes));
        self
    }

    /// Whether the prefix cache is enabled — which is also the
    /// precondition for MID-FLIGHT saves on the serving path (capture
    /// is only armed for every packed request when the cache is on; a
    /// request submitted with `save: true` always captures).
    pub fn cache_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Cap on retained conversations (default 256): beyond it the
    /// least-recently-resumed snapshot is dropped — the saved store is
    /// bounded like the prefix store, never an unbounded memory sink.
    pub fn with_max_saved(mut self, max: usize) -> Self {
        self.max_saved = max.max(1);
        self
    }

    /// Saved conversations currently retained ([`ResumeFrom::Token`]).
    pub fn saved_conversations(&self) -> usize {
        self.saved.len()
    }

    pub fn config(&self) -> &ModelConfig {
        self.backend.config()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Shared handle to the live counters (snapshot-safe from other
    /// threads while the engine runs).
    pub fn stats_handle(&self) -> Arc<EngineStats> {
        self.stats.clone()
    }

    /// Measure per-step costs and install a calibrated fallback policy
    /// (used by `mode = Auto`; see Table 9).
    pub fn calibrate(&mut self, iters: usize) -> Result<Calibration> {
        let cfg = self.backend.config().clone();
        let l = cfg.n_layers;
        let x = Tensor::zeros(&[l, cfg.seg_total, cfg.d_model]);
        let a = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
        let z = Tensor::zeros(&[l, cfg.phi_dim]);
        let mask = vec![1.0; l];
        // warmup + timed grouped steps
        self.backend.grouped_step(&x, &a, &z, &mask)?;
        let t0 = Instant::now();
        for _ in 0..iters.max(1) {
            self.backend.grouped_step(&x, &a, &z, &mask)?;
        }
        let grouped_step_s = t0.elapsed().as_secs_f64() / iters.max(1) as f64;

        let x1 = x.index0(0);
        let a1 = a.index0(0);
        let z1 = z.index0(0);
        self.backend.single_step(0, &x1, &a1, &z1)?;
        let t0 = Instant::now();
        for _ in 0..iters.max(1) {
            self.backend.single_step(0, &x1, &a1, &z1)?;
        }
        let single_step_s = t0.elapsed().as_secs_f64() / iters.max(1) as f64;

        let cal = Calibration { grouped_step_s, single_step_s, n_layers: l };
        self.policy = FallbackPolicy::Calibrated(cal);
        Ok(cal)
    }

    fn resolve_mode(&self, req: &GenerateRequest, n_segments: usize) -> ExecMode {
        let mode = req.mode.unwrap_or(self.mode);
        match mode {
            ExecMode::Auto => {
                if self.policy.use_diagonal(n_segments) {
                    ExecMode::Diagonal
                } else {
                    ExecMode::Sequential
                }
            }
            m => m,
        }
    }

    /// Reject obviously bad requests before they reach a scheduler.
    fn validate(&self, req: &GenerateRequest) -> Result<()> {
        if req.prompt.is_empty() {
            self.stats.rejected.inc();
            return Err(Error::Request("empty token sequence".into()));
        }
        if req.prompt.len() + req.max_new_tokens > self.max_request_tokens {
            self.stats.rejected.inc();
            return Err(Error::Request(format!(
                "request of {} prompt + {} new tokens exceeds limit {}",
                req.prompt.len(),
                req.max_new_tokens,
                self.max_request_tokens
            )));
        }
        if let Err(e) = req.sampling.validate() {
            self.stats.rejected.inc();
            return Err(e);
        }
        Ok(())
    }

    /// Resolve a request's prefill: segment the prompt, resolve any
    /// resume source, and — when the cache is enabled — look up the
    /// longest cached prefix (capped one short of the full prompt, so
    /// at least one segment always runs and produces exit logits).
    fn plan_prefill(&mut self, req: &GenerateRequest) -> Result<PrefillPlan> {
        let cfg = self.backend.config();
        let blocks = segment_tokens(cfg, &req.prompt)?;
        if let Some(resume) = &req.resume {
            let snap = match resume {
                ResumeFrom::Snapshot(s) => (**s).clone(),
                ResumeFrom::Token(t) => {
                    self.saved_clock += 1;
                    let clock = self.saved_clock;
                    let saved = self.saved.get_mut(t).ok_or_else(|| {
                        Error::Request(format!(
                            "unknown resume token {t} (conversation not saved, or evicted)"
                        ))
                    })?;
                    saved.last_used = clock;
                    saved.snap.clone()
                }
            };
            snap.validate_for(cfg)?;
            let reused = snap.segments;
            return Ok(PrefillPlan {
                total_prompt: reused + blocks.len(),
                reused,
                segments: blocks,
                snapshot: Some(snap),
                blocks: None,
            });
        }
        let mut reused = 0;
        let mut snapshot = None;
        if let Some(store) = &mut self.cache {
            if blocks.len() > 1 {
                if let Some((depth, snap)) = store.lookup(&blocks[..blocks.len() - 1]) {
                    reused = depth;
                    snapshot = Some(snap);
                    self.stats.cache_hits.inc();
                    self.stats.cache_hit_segments.add(depth as u64);
                }
            }
            self.stats.cache_bytes.set(store.bytes() as u64);
        }
        Ok(PrefillPlan {
            segments: blocks[reused..].to_vec(),
            snapshot,
            total_prompt: blocks.len(),
            reused,
            blocks: Some(blocks),
        })
    }

    /// Insert an after-segment snapshot (absolute index `index`) into
    /// the prefix store, keyed by the history blocks up to and
    /// including that segment.
    fn insert_prefix(&mut self, blocks: &Option<Vec<Vec<u32>>>, index: usize, snap: MemSnapshot) {
        let (Some(store), Some(blocks)) = (&mut self.cache, blocks) else { return };
        if index + 1 > blocks.len() {
            return; // not a prompt segment of a known history
        }
        debug_assert_eq!(snap.segments, index + 1);
        let evicted = store.insert(&blocks[..index + 1], snap);
        self.stats.cache_evictions.add(evicted);
        self.stats.cache_bytes.set(store.bytes() as u64);
    }

    /// Fold a completed request's final memory state into the saved
    /// conversations (save flag) and the prefix store (the decoded
    /// history becomes a reusable prefix for follow-up turns). Returns
    /// what the terminal [`Response`] should carry.
    fn retain_final(
        &mut self,
        handle: &RequestHandle,
        blocks: &Option<Vec<Vec<u32>>>,
        total_prompt: usize,
        driver: &GenDriver,
        final_state: Option<MemSnapshot>,
    ) -> (Option<u64>, Option<MemSnapshot>) {
        let Some(snap) = final_state else { return (None, None) };
        let seg = self.backend.config().seg;
        // Segments the decode phase actually fed back: history = prompt
        // blocks + those (always full) segments. The final emitted
        // tokens of an exhausted budget belong to a segment that was
        // never fed, so they are NOT part of the cached recurrence.
        let fed_decode = driver.fed.saturating_sub(total_prompt);
        if fed_decode > 0 && self.cache.is_some() && blocks.is_some() {
            let mut history = blocks.clone().expect("checked above");
            for chunk in driver.generated[..fed_decode * seg].chunks(seg) {
                history.push(chunk.to_vec());
            }
            debug_assert_eq!(history.len(), snap.segments);
            let depth = history.len() - 1;
            self.insert_prefix(&Some(history), depth, snap.clone());
        }
        if handle.save_requested() {
            // Engine-assigned tokens: unique per engine, so one
            // client's save can never overwrite another conversation.
            let token = self.next_resume_token;
            self.next_resume_token += 1;
            self.saved_clock += 1;
            self.saved.insert(
                token,
                SavedConversation { snap: snap.clone(), last_used: self.saved_clock },
            );
            // Bounded retention: drop the least-recently-resumed
            // conversation beyond the cap.
            while self.saved.len() > self.max_saved {
                let Some(&oldest) = self
                    .saved
                    .iter()
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(k, _)| k)
                else {
                    break;
                };
                self.saved.remove(&oldest);
                self.stats.cache_evictions.inc();
            }
            (Some(token), Some(snap))
        } else {
            (None, None)
        }
    }

    /// Fold one finished run into the aggregate utilization counters.
    /// Full-attention runs execute no wavefront slots (`slot_steps = 0`)
    /// and are skipped — recording them would dilute `mean_group` with
    /// launches that carry no cells.
    fn record_run(&self, stats: &RunStats) {
        if stats.slot_steps == 0 {
            return;
        }
        self.stats.launches.add(stats.launches);
        self.stats
            .occupancy
            .add(stats.slot_steps - stats.padded_cells, stats.slot_steps);
    }

    /// Execute one request synchronously, discarding intermediate
    /// events — the collect-all-events special case of
    /// [`generate`](Self::generate), and the oracle the bit-exactness
    /// tests run both schedules through.
    pub fn process(&mut self, req: &GenerateRequest) -> Result<Response> {
        self.run_request(req, &mut |_| {})
    }

    /// Execute one request, streaming its [`Event`]s to `emit` as they
    /// happen. Always ends with a terminal event (`Done` on success —
    /// also the `Ok` return — or `Error`, mirrored in the `Err`).
    pub fn generate<F: FnMut(Event)>(&mut self, req: &GenerateRequest, mut emit: F) -> Result<()> {
        match self.run_request(req, &mut emit) {
            Ok(resp) => {
                emit(Event::Done { stats: Box::new(resp) });
                Ok(())
            }
            Err(e) => {
                emit(Event::Error { error: e.duplicate() });
                Err(e)
            }
        }
    }

    /// Single-shot dispatch: validates, resolves the mode, runs the
    /// request to completion on this thread, updates the counters.
    fn run_request<F: FnMut(Event)>(
        &mut self,
        req: &GenerateRequest,
        emit: &mut F,
    ) -> Result<Response> {
        self.validate(req)?;
        let n_segments = req.prompt.len().div_ceil(self.backend.config().seg);
        let mode = self.resolve_mode(req, n_segments);
        let started = Instant::now();

        let resp = match mode {
            ExecMode::FullAttention => {
                if req.max_new_tokens > 0 {
                    self.stats.rejected.inc();
                    return Err(Error::Config(
                        "full-attention mode does not support generation \
                         (decode is segment-recurrent; use diagonal or sequential)"
                            .into(),
                    ));
                }
                if req.resume.is_some() || req.save_requested() {
                    self.stats.rejected.inc();
                    return Err(Error::Config(
                        "full-attention mode has no recurrent memory state to save \
                         or resume (use diagonal or sequential)"
                            .into(),
                    ));
                }
                self.stats.full_attn_runs.inc();
                let t0 = Instant::now();
                let out = self.backend.full_attn(&req.prompt)?;
                let stats = RunStats {
                    mode_diagonal: false,
                    segments: 1,
                    launches: 1,
                    cells: 0,
                    slot_steps: 0,
                    padded_cells: 0,
                    wall: t0.elapsed(),
                    tokens: req.prompt.len(),
                };
                let greedy_tail = out.argmax_rows();
                Response {
                    id: req.id,
                    greedy_tail,
                    generated: Vec::new(),
                    logits: req.want_logits.then(|| vec![out]),
                    reused_segments: 0,
                    segments_skipped: 0,
                    overflow_routed: false,
                    saturation: 0.0,
                    resume_token: None,
                    final_state: None,
                    mode_used: ExecMode::FullAttention,
                    stats,
                    latency: started.elapsed(),
                    trace: req.trace,
                }
            }
            ExecMode::Diagonal => {
                self.stats.diagonal_runs.inc();
                self.run_diagonal_streaming(req, emit, started)?
            }
            ExecMode::Sequential => {
                self.stats.sequential_runs.inc();
                self.run_sequential_streaming(req, emit, started)?
            }
            ExecMode::Auto => unreachable!("resolved above"),
        };

        self.stats.requests.inc();
        self.stats.tokens.add(req.prompt.len() as u64);
        self.stats.generated_tokens.add(resp.generated.len() as u64);
        self.stats.latency.observe(resp.latency);
        self.record_run(&resp.stats);
        Ok(resp)
    }

    /// Diagonal prefill + in-wavefront decode as a one-request, 1-lane
    /// session — the same machinery `serve_queue` packs many requests
    /// into.
    fn run_diagonal_streaming<F: FnMut(Event)>(
        &mut self,
        req: &GenerateRequest,
        emit: &mut F,
        started: Instant,
    ) -> Result<Response> {
        let cfg = self.backend.config().clone();
        let chunk_eligible =
            req.overflow == OverflowPolicy::Chunked && req.resume.is_none();
        // Chunked routing, predicted: a prompt whose fill alone pins the
        // eventual saturation over the threshold never starts the full
        // run.
        if chunk_eligible
            && quality::predicted_saturation(&cfg, req.prompt.len()) > quality::CHUNK_THRESHOLD
        {
            return self.chunked_rerun(req, emit, started, ExecMode::Diagonal);
        }
        let plan = self.plan_prefill(req)?;
        let (total_prompt, reused, blocks) = (plan.total_prompt, plan.reused, plan.blocks);
        // Segment selection: gate the memory write for low-scoring
        // prompt segments. Decided up front from token ids alone, so
        // the decision is deterministic across schedules and threads.
        let gates: HashSet<usize> = if req.overflow == OverflowPolicy::Select {
            quality::plan_selection(&plan.segments)
                .iter()
                .enumerate()
                .filter(|(_, &skip)| skip)
                .map(|(i, _)| reused + i)
                .collect()
        } else {
            HashSet::new()
        };
        // Gated runs must never feed the shared prefix store: their
        // boundary states embody this request's selection policy and
        // would leak into policy-off requests with the same prefix.
        let blocks = if gates.is_empty() { blocks } else { None };
        let mut session = WavefrontSession::new(cfg.clone(), 1);
        match plan.snapshot {
            Some(snap) => {
                session.submit_stream_resumed(0, snap, plan.segments, req.want_logits)?
            }
            None => session.submit_stream(0, plan.segments, req.want_logits)?,
        }
        if !gates.is_empty() {
            self.stats.segments_skipped.add(gates.len() as u64);
            session.set_memory_gates(0, gates.clone())?;
        }
        let handle = req.handle();
        // Snapshot capture: prompt-boundary states feed the prefix
        // store, the final state feeds conversation save/resume.
        if handle.save_requested() || self.cache.is_some() {
            session.capture_final(0)?;
        }
        if self.cache.is_some() && blocks.is_some() {
            for idx in reused..total_prompt {
                session.capture_after(0, idx)?;
            }
        }
        if req.max_new_tokens == 0 {
            session.finish_stream(0)?;
        }
        let mut monitor = MemoryMonitor::new(&cfg);
        if reused > 0 {
            // Resumed / prefix-hit history already occupies memory.
            monitor.observe(reused * cfg.seg, None);
        }
        let mut driver = GenDriver::new(req, total_prompt);
        let deadline = req.deadline.map(|d| started + d);
        // Span bookkeeping for the single-shot path (one lane, tid 0).
        let tr_id = span_trace_id(req);
        let tracing = tr_id != 0 && trace::enabled();
        let req_start_us = if tracing { trace::now_us() } else { 0 };
        let mut last_span_us = req_start_us;
        let mut last_token_at: Option<Instant> = None;
        let engine_stats = self.stats.clone();
        loop {
            if req.is_cancelled() {
                session.cancel(0);
                self.stats.cancelled.inc();
                if tracing {
                    trace::complete(
                        "request",
                        req_start_us,
                        0,
                        vec![
                            ("trace", Value::Num(tr_id as f64)),
                            ("id", Value::Num(req.id as f64)),
                            ("cancelled", Value::Bool(true)),
                        ],
                    );
                }
                return Err(Error::Request("cancelled".into()));
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                session.cancel(0);
                self.stats.cancelled.inc();
                if tracing {
                    trace::complete(
                        "request",
                        req_start_us,
                        0,
                        vec![
                            ("trace", Value::Num(tr_id as f64)),
                            ("id", Value::Num(req.id as f64)),
                            ("cancelled", Value::Bool(true)),
                            ("reason", Value::Str("deadline exceeded".into())),
                        ],
                    );
                }
                return Err(Error::Request("deadline exceeded".into()));
            }
            let progressed = session.step(&mut self.backend)?;
            while let Some(exit) = session.pop_exited() {
                if let Some(snap) = exit.snapshot {
                    let insert_start_us = if tracing { trace::now_us() } else { 0 };
                    self.insert_prefix(&blocks, exit.index, snap);
                    if tracing {
                        trace::complete(
                            "cache_insert",
                            insert_start_us,
                            0,
                            vec![
                                ("trace", Value::Num(tr_id as f64)),
                                ("segment", Value::Num(exit.index as f64)),
                            ],
                        );
                    }
                }
                let written = if gates.contains(&exit.index) { 0 } else { cfg.seg };
                monitor.observe(written, Some(&exit.signals));
                let sat = monitor.saturation();
                self.stats.saturation_milli.set((sat * 1e3).round() as u64);
                // Chunked routing, observed: the energy signals crossed
                // the threshold mid-prefill — abandon the overflowing
                // run and answer from the best capacity-sized window.
                if chunk_eligible
                    && exit.index + 1 < total_prompt
                    && sat > quality::CHUNK_THRESHOLD
                {
                    session.cancel(0);
                    return self.chunked_rerun(req, emit, started, ExecMode::Diagonal);
                }
                // Segment residency: previous boundary -> this exit.
                if tracing {
                    let name = if exit.index < total_prompt {
                        "prefill_segment"
                    } else {
                        "decode_segment"
                    };
                    trace::complete(
                        name,
                        last_span_us,
                        0,
                        vec![
                            ("trace", Value::Num(tr_id as f64)),
                            ("id", Value::Num(req.id as f64)),
                            ("segment", Value::Num(exit.index as f64)),
                        ],
                    );
                    last_span_us = trace::now_us();
                }
                let action = driver.on_exit(exit.index, &exit.logits, sat, &mut |ev| {
                    if let Event::Token { pos, .. } = &ev {
                        let now = Instant::now();
                        match last_token_at {
                            None => engine_stats.ttft.observe(now.duration_since(started)),
                            Some(prev) => {
                                engine_stats.inter_token.observe(now.duration_since(prev))
                            }
                        }
                        last_token_at = Some(now);
                        if tracing {
                            trace::record(TraceEvent {
                                name: "decode_token",
                                ts_us: trace::now_us(),
                                dur_us: 0,
                                tid: 0,
                                args: vec![
                                    ("trace", Value::Num(tr_id as f64)),
                                    ("pos", Value::Num(*pos as f64)),
                                ],
                            });
                        }
                    }
                    emit(ev)
                });
                match action {
                    ExitAction::Wait => {}
                    ExitAction::Feed(seg) => session.append_segment(0, seg)?,
                    ExitAction::Finish => session.finish_stream(0)?,
                }
            }
            if let Some(out) = session.pop_completed() {
                let mut stats = out.stats;
                stats.wall = started.elapsed();
                if tracing {
                    trace::complete(
                        "request",
                        req_start_us,
                        0,
                        vec![
                            ("trace", Value::Num(tr_id as f64)),
                            ("id", Value::Num(req.id as f64)),
                            ("prompt_tokens", Value::Num(req.prompt.len() as f64)),
                            ("generated", Value::Num(driver.generated.len() as f64)),
                            ("reused_segments", Value::Num(reused as f64)),
                        ],
                    );
                }
                let (resume_token, final_state) = self.retain_final(
                    &handle,
                    &blocks,
                    total_prompt,
                    &driver,
                    out.final_state,
                );
                return Ok(Response {
                    id: req.id,
                    greedy_tail: driver.last_greedy,
                    generated: driver.generated,
                    logits: req.want_logits.then_some(out.logits),
                    reused_segments: reused,
                    segments_skipped: gates.len(),
                    overflow_routed: false,
                    saturation: monitor.saturation(),
                    resume_token,
                    final_state,
                    mode_used: ExecMode::Diagonal,
                    stats,
                    latency: started.elapsed(),
                    trace: req.trace,
                });
            }
            if !progressed {
                return Err(Error::Schedule(
                    "wavefront idled before the request completed".into(),
                ));
            }
        }
    }

    /// Sequential prefill + decode: the baseline ARMT loop extended
    /// segment-by-segment — the second, independent implementation of
    /// the exact same recurrence (and the generation oracle).
    fn run_sequential_streaming<F: FnMut(Event)>(
        &mut self,
        req: &GenerateRequest,
        emit: &mut F,
        started: Instant,
    ) -> Result<Response> {
        let cfg = self.backend.config().clone();
        let l_total = cfg.n_layers;
        let calls0 = self.backend.step_calls();
        let chunk_eligible =
            req.overflow == OverflowPolicy::Chunked && req.resume.is_none();
        if chunk_eligible
            && quality::predicted_saturation(&cfg, req.prompt.len()) > quality::CHUNK_THRESHOLD
        {
            return self.chunked_rerun(req, emit, started, ExecMode::Sequential);
        }
        let plan = self.plan_prefill(req)?;
        let (total_prompt, reused, blocks) = (plan.total_prompt, plan.reused, plan.blocks);
        let mut segments = plan.segments;
        // Segment selection: same decision rule and gate set as the
        // wavefront path — the skipped writeback below is the
        // sequential mirror of the session's gate save/restore.
        let gates: HashSet<usize> = if req.overflow == OverflowPolicy::Select {
            quality::plan_selection(&segments)
                .iter()
                .enumerate()
                .filter(|(_, &skip)| skip)
                .map(|(i, _)| reused + i)
                .collect()
        } else {
            HashSet::new()
        };
        if !gates.is_empty() {
            self.stats.segments_skipped.add(gates.len() as u64);
        }
        let blocks = if gates.is_empty() { blocks } else { None };
        let mut driver = GenDriver::new(req, total_prompt);
        let handle = req.handle();
        let deadline = req.deadline.map(|d| started + d);
        // Span bookkeeping (the oracle path gets the same taxonomy so
        // off/on comparisons can trace both sides).
        let tr_id = span_trace_id(req);
        let tracing = tr_id != 0 && trace::enabled();
        let req_start_us = if tracing { trace::now_us() } else { 0 };
        let mut last_span_us = req_start_us;

        // Per-layer recurrent state — seeded from the snapshot on a
        // prefix hit / resume (the sequential loop is the second,
        // independent implementation of the same seeding rule).
        let (mut a, mut z): (Vec<Tensor>, Vec<Tensor>) = match plan.snapshot {
            Some(snap) => (snap.a, snap.z),
            None => (
                (0..l_total).map(|_| Tensor::zeros(&[cfg.d_model, cfg.phi_dim])).collect(),
                (0..l_total).map(|_| Tensor::zeros(&[cfg.phi_dim])).collect(),
            ),
        };
        let snapshot_now = |a: &[Tensor], z: &[Tensor], consumed: usize| {
            MemSnapshot::from_layers(
                &cfg,
                consumed,
                a.iter().cloned().zip(z.iter().cloned()).collect(),
            )
            .ok()
        };

        let mut monitor = MemoryMonitor::new(&cfg);
        if reused > 0 {
            monitor.observe(reused * cfg.seg, None);
        }
        // Per-layer `‖A‖²`, updated only on real writebacks — the same
        // energy accounting the wavefront session keeps per slot.
        let mut layer_energy: Vec<f64> = a
            .iter()
            .map(|t| t.data().iter().map(|&v| (v as f64) * (v as f64)).sum())
            .collect();
        let mut logits_acc = Vec::new();
        let mut idx = 0;
        while idx < segments.len() {
            if req.is_cancelled() {
                self.stats.cancelled.inc();
                return Err(Error::Request("cancelled".into()));
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                self.stats.cancelled.inc();
                return Err(Error::Request("deadline exceeded".into()));
            }
            let abs = reused + idx;
            let gated = gates.contains(&abs);
            let mut x = self.backend.embed(&segments[idx])?;
            let mut update_energy = 0.0f64;
            for l in 0..l_total {
                let (y, a2, z2) = self.backend.single_step(l, &x, &a[l], &z[l])?;
                x = y;
                if !gated {
                    let e: f64 =
                        a2.data().iter().map(|&v| (v as f64) * (v as f64)).sum();
                    update_energy += (e - layer_energy[l]).abs();
                    layer_energy[l] = e;
                    a[l] = a2;
                    z[l] = z2;
                }
            }
            let logits = self.backend.lm_head(&x)?;
            // Prompt-boundary snapshot into the prefix store (same
            // policy as the wavefront path's targeted captures).
            if self.cache.is_some() && blocks.is_some() && abs < total_prompt {
                if let Some(snap) = snapshot_now(&a, &z, abs + 1) {
                    self.insert_prefix(&blocks, abs, snap);
                }
            }
            let state_energy: f64 = layer_energy.iter().sum();
            monitor.observe(
                if gated { 0 } else { cfg.seg },
                Some(&SegmentSignals { update_energy, state_energy }),
            );
            let sat = monitor.saturation();
            self.stats.saturation_milli.set((sat * 1e3).round() as u64);
            if chunk_eligible && abs + 1 < total_prompt && sat > quality::CHUNK_THRESHOLD {
                return self.chunked_rerun(req, emit, started, ExecMode::Sequential);
            }
            if tracing {
                let name =
                    if abs < total_prompt { "prefill_segment" } else { "decode_segment" };
                trace::complete(
                    name,
                    last_span_us,
                    0,
                    vec![
                        ("trace", Value::Num(tr_id as f64)),
                        ("id", Value::Num(req.id as f64)),
                        ("segment", Value::Num(abs as f64)),
                    ],
                );
                last_span_us = trace::now_us();
            }
            match driver.on_exit(abs, &logits, sat, emit) {
                ExitAction::Wait | ExitAction::Finish => {}
                ExitAction::Feed(seg) => segments.push(seg),
            }
            if req.want_logits {
                logits_acc.push(logits);
            }
            idx += 1;
        }
        if tracing {
            trace::complete(
                "request",
                req_start_us,
                0,
                vec![
                    ("trace", Value::Num(tr_id as f64)),
                    ("id", Value::Num(req.id as f64)),
                    ("prompt_tokens", Value::Num(req.prompt.len() as f64)),
                    ("generated", Value::Num(driver.generated.len() as f64)),
                ],
            );
        }

        let s_total = segments.len();
        let cells = (s_total * l_total) as u64;
        let stats = RunStats {
            mode_diagonal: false,
            segments: s_total,
            launches: self.backend.step_calls() - calls0,
            cells,
            slot_steps: cells,
            padded_cells: 0,
            wall: started.elapsed(),
            tokens: s_total * cfg.seg,
        };
        let want_final = handle.save_requested()
            || (self.cache.is_some() && blocks.is_some() && driver.fed > total_prompt);
        let final_state =
            if want_final { snapshot_now(&a, &z, reused + s_total) } else { None };
        let (resume_token, final_state) =
            self.retain_final(&handle, &blocks, total_prompt, &driver, final_state);
        Ok(Response {
            id: req.id,
            greedy_tail: driver.last_greedy,
            generated: driver.generated,
            logits: req.want_logits.then_some(logits_acc),
            reused_segments: reused,
            segments_skipped: gates.len(),
            overflow_routed: false,
            saturation: monitor.saturation(),
            resume_token,
            final_state,
            mode_used: ExecMode::Sequential,
            stats,
            latency: started.elapsed(),
            trace: req.trace,
        })
    }

    /// Chunked fallback (`overflow: "chunked"`): re-run the request over
    /// the best capacity-sized window of its context plus the final
    /// (query-carrying) segment, instead of letting the full prompt
    /// overflow the associative memory. The sub-run executes with the
    /// policy off — no recursive re-routing — and its event stream
    /// restarts over the reduced context (segment indices count from 0
    /// within the window).
    fn chunked_rerun<F: FnMut(Event)>(
        &mut self,
        req: &GenerateRequest,
        emit: &mut F,
        started: Instant,
        mode: ExecMode,
    ) -> Result<Response> {
        let (seg, window_segs) = {
            let cfg = self.backend.config();
            (cfg.seg, (cfg.phi_dim / cfg.seg).max(1))
        };
        let blocks = quality::segment_tokens(&req.prompt, seg);
        let (lo, hi) = quality::choose_window(&blocks, window_segs);
        let mut prompt: Vec<u32> =
            blocks[lo..hi].iter().flat_map(|b| b.iter().copied()).collect();
        // The query segment is excluded from the window search and
        // always rides along (validate() guarantees a nonempty prompt).
        prompt.extend_from_slice(blocks.last().expect("validated: nonempty prompt"));
        let mut sub = req.clone();
        sub.prompt = prompt;
        sub.overflow = OverflowPolicy::Off;
        self.stats.overflow_routed.inc();
        if trace::enabled() {
            trace::record(TraceEvent {
                name: "overflow_route",
                ts_us: trace::now_us(),
                dur_us: 0,
                tid: TID_CONTROL,
                args: vec![
                    ("id", Value::Num(req.id as f64)),
                    ("window_lo", Value::Num(lo as f64)),
                    ("window_hi", Value::Num(hi as f64)),
                ],
            });
        }
        let mut resp = match mode {
            ExecMode::Sequential => self.run_sequential_streaming(&sub, emit, started)?,
            _ => self.run_diagonal_streaming(&sub, emit, started)?,
        };
        resp.overflow_routed = true;
        Ok(resp)
    }

    /// Continuous-batching drain loop (the serving path).
    ///
    /// Pulls `(GenerateRequest, ticket)` jobs from `queue`, packs every
    /// diagonal-mode request into one persistent [`WavefrontSession`]
    /// (lanes from [`with_lanes`](Self::with_lanes)), and streams each
    /// request's [`Event`]s through `emit` with its ticket — generally
    /// interleaved across requests and OUT of submission order, since
    /// short requests overtake long ones. Decode happens inside the
    /// live wavefront: a request whose prefill drained gets its sampled
    /// continuation appended to its lane, so concurrent generations
    /// keep sharing grouped launches. Cancellation handles and
    /// deadlines are polled between iterations; evicted requests
    /// terminate with [`Event::Error`] and free their lane immediately.
    /// Admission happens between wavefront iterations: the queue is
    /// polled non-blockingly while requests are in flight and blockingly
    /// when the wavefront is empty. Returns when the queue is closed and
    /// everything in flight has completed.
    ///
    /// `queue` is any [`JobSource`](crate::coordinator::JobSource) —
    /// the FIFO [`RequestQueue`] or the gateway's weighted-fair
    /// [`FairScheduler`](crate::gateway::FairScheduler). Admission
    /// *order* is the source's policy; each admitted request's event
    /// stream stays bit-exact regardless (the P7/P12/P13 invariant).
    ///
    /// Generation requests always pack into the wavefront (decode is
    /// diagonal-native; `Auto` routes them there regardless of prompt
    /// length). An *explicit* sequential/full-attention override with a
    /// decode budget is refused with [`Event::Error`] — running it
    /// inline would monopolize the engine thread for the whole decode,
    /// stalling every packed request. Prefill-only overrides still run
    /// inline between iterations, bounded by their prompt.
    ///
    /// # Examples
    ///
    /// Drain a burst of generation requests through one packed wavefront
    /// (the ticket type `T` is whatever the caller needs to route
    /// replies — the TCP server uses an `mpsc::Sender<Event>`, this
    /// example an index):
    ///
    /// ```no_run
    /// use diagonal_batching::config::{ExecMode, Manifest};
    /// use diagonal_batching::coordinator::{
    ///     Event, GenerateRequest, InferenceEngine, RequestQueue,
    /// };
    /// use diagonal_batching::model::{NativeBackend, Params};
    ///
    /// let manifest = Manifest::load("artifacts/manifest.json").unwrap();
    /// let entry = manifest.model("tiny").unwrap();
    /// let backend =
    ///     NativeBackend::new(entry.config.clone(), Params::load(&manifest, "tiny").unwrap());
    /// let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(2);
    ///
    /// let queue: RequestQueue<(GenerateRequest, usize)> = RequestQueue::new(8);
    /// for i in 0..4u64 {
    ///     let prompt: Vec<u32> = (0..128).map(|t| t % 100).collect();
    ///     queue.push((GenerateRequest::new(i, prompt).generate(64), i as usize)).unwrap();
    /// }
    /// queue.close(); // a live server keeps pushing instead
    /// engine.serve_queue(&queue, |ticket, event| match event {
    ///     Event::Token { pos, token } => println!("request #{ticket}: token[{pos}] = {token}"),
    ///     Event::Done { stats } => println!("request #{ticket} done: {:?}", stats.latency),
    ///     Event::Error { error } => eprintln!("request #{ticket} failed: {error}"),
    ///     _ => {}
    /// }).unwrap();
    /// // p50/p90/p99 of everything served, as `{"cmd": "stats"}` reports:
    /// let stats = engine.stats_handle();
    /// println!("p99 {:?}", stats.latency.quantile(0.99));
    /// ```
    pub fn serve_queue<T, Q, F>(&mut self, queue: &Q, mut emit: F) -> Result<()>
    where
        Q: crate::coordinator::queue::JobSource<(GenerateRequest, T)>,
        F: FnMut(&T, Event),
    {
        let mut session = WavefrontSession::new(self.backend.config().clone(), self.lanes);
        let seg_len = self.backend.config().seg;
        // Cloned handle for the token-timing closure in the exit loop
        // (which cannot borrow `self` while the ticket is borrowed).
        let engine_stats = self.stats.clone();
        let mut tickets: HashMap<u64, ServeTicket<T>> = HashMap::new();
        // Session keys are engine-local: wire ids may collide across
        // connections, in-flight keys must not.
        let mut next_key: u64 = 0;
        let mut last = session.stats();
        let mut last_ws = self.backend.worker_stats();
        let mut last_wall = Instant::now();
        let mut last_kernel = crate::tensor::kernel_totals();
        self.stats.workers.set(last_ws.threads as u64);
        loop {
            // Admission. Block only when the wavefront is empty; keep
            // the backlog shallow so queue backpressure stays honest.
            if session.is_idle() {
                match queue.pop_job() {
                    None => break, // closed and drained
                    Some(job) => {
                        self.admit(job, &mut session, &mut tickets, &mut next_key, &mut emit);
                    }
                }
            }
            while session.backlog() < session.lanes() {
                match queue.try_pop_job() {
                    Some(job) => {
                        let packed = self.admit(
                            job,
                            &mut session,
                            &mut tickets,
                            &mut next_key,
                            &mut emit,
                        );
                        // A non-diagonal job was executed single-shot
                        // inline; bound that to one per wavefront
                        // iteration so in-flight packed requests are
                        // never stalled behind an unbounded run of
                        // sequential overrides.
                        if !packed {
                            break;
                        }
                    }
                    None => break,
                }
            }

            // Cancellations and deadlines, polled between iterations so
            // an evicted request frees its lane before the next launch.
            let now = Instant::now();
            let expired: Vec<u64> = tickets
                .iter()
                .filter(|(_, t)| {
                    t.handle.is_cancelled() || t.deadline.is_some_and(|d| now >= d)
                })
                .map(|(k, _)| *k)
                .collect();
            for key in expired {
                let t = tickets.remove(&key).expect("collected above");
                session.cancel(key);
                self.stats.cancelled.inc();
                let why =
                    if t.handle.is_cancelled() { "cancelled" } else { "deadline exceeded" };
                if t.tr.started_us != 0 && trace::enabled() {
                    trace::complete(
                        "request",
                        t.tr.started_us,
                        t.tr.lane,
                        vec![
                            ("trace", Value::Num(t.tr.id as f64)),
                            ("id", Value::Num(t.wire_id as f64)),
                            ("cancelled", Value::Bool(true)),
                            ("reason", Value::Str(why.into())),
                        ],
                    );
                }
                emit(&t.ticket, Event::Error { error: Error::Request(why.into()) });
            }

            // One wavefront iteration.
            let iter_start_us = if trace::enabled() { trace::now_us() } else { 0 };
            if let Err(e) = session.step(&mut self.backend) {
                let msg = e.to_string();
                for (_, t) in tickets.drain() {
                    emit(
                        &t.ticket,
                        Event::Error {
                            error: Error::Schedule(format!("wavefront aborted: {msg}")),
                        },
                    );
                }
                return Err(e);
            }

            // Aggregate utilization: session-level deltas (per-request
            // windows overlap, so they cannot be summed). Recorded
            // BEFORE the completion events fire, so a client that
            // queries stats right after its reply sees its own
            // launches/occupancy included.
            let now = session.stats();
            let d_launches = now.launches - last.launches;
            let d_cells = now.cells - last.cells;
            let d_slots = now.slot_steps - last.slot_steps;
            self.stats.launches.add(d_launches);
            self.stats.occupancy.add(d_cells, d_slots);
            last = now;

            // Worker utilization: pool busy-time delta over the worker
            // capacity of this iteration's wall-time. Busy time is
            // measured inside the workers, so clamp to capacity — a
            // stats read must never trip the Ratio invariant.
            let ws = self.backend.worker_stats();
            let wall_us = last_wall.elapsed().as_micros() as u64;
            last_wall = Instant::now();
            let capacity_us = (ws.threads.max(1) as u64).saturating_mul(wall_us);
            let busy_us = ws.busy_us.saturating_sub(last_ws.busy_us).min(capacity_us);
            self.stats.pool_cells.add(ws.pool_cells.saturating_sub(last_ws.pool_cells));
            self.stats.worker_busy.add(busy_us, capacity_us);
            last_ws = ws;

            // Kernel-tier deltas (process-global counters, same
            // snapshot-and-subtract scheme as the pool stats above):
            // the flops the GEMM tier retired this iteration and the
            // time it spent retiring them.
            let kt = crate::tensor::kernel_totals();
            let d_kernel_ns = kt.1.saturating_sub(last_kernel.1);
            self.stats.kernel_flops.add(kt.0.saturating_sub(last_kernel.0));
            self.stats.kernel_ns.add(d_kernel_ns);
            last_kernel = kt;

            // Wavefront timeline row: one complete event per iteration
            // on the reserved profiler track, carrying this iteration's
            // group size, padded cells and kernel time — the Perfetto
            // view of the paper's diagonal.
            if iter_start_us != 0 && d_slots > 0 {
                trace::record(TraceEvent {
                    name: "wavefront_step",
                    ts_us: iter_start_us,
                    dur_us: trace::now_us().saturating_sub(iter_start_us),
                    tid: TID_WAVEFRONT,
                    args: vec![
                        ("group", Value::Num(d_cells as f64)),
                        ("padded", Value::Num(d_slots.saturating_sub(d_cells) as f64)),
                        ("launches", Value::Num(d_launches as f64)),
                        ("kernel_ms", Value::Num(d_kernel_ns as f64 / 1e6)),
                        ("in_flight", Value::Num(tickets.len() as f64)),
                    ],
                });
            }

            // Segment exits: stream partial results and run the decode
            // hand-off — sample the frontier's continuation and feed it
            // back into the same live wavefront. Prompt-boundary
            // snapshots riding the exits go into the prefix store.
            while let Some(exit) = session.pop_exited() {
                let lane = session.lane_of(exit.id).map(|l| l as u64).unwrap_or(TID_CONTROL);
                let Some(t) = tickets.get_mut(&exit.id) else { continue };
                let tracing = t.tr.started_us != 0 && trace::enabled();
                t.tr.lane = lane;
                let checkpoint = t.checkpoint;
                if let Some(snap) = exit.snapshot {
                    if checkpoint {
                        emit(
                            &t.ticket,
                            Event::Snapshot {
                                index: exit.index,
                                state: Box::new(snap.clone()),
                            },
                        );
                    }
                    let insert_start_us = if tracing { trace::now_us() } else { 0 };
                    self.insert_prefix(&t.blocks, exit.index, snap);
                    if tracing {
                        trace::complete(
                            "cache_insert",
                            insert_start_us,
                            lane,
                            vec![
                                ("trace", Value::Num(t.tr.id as f64)),
                                ("segment", Value::Num(exit.index as f64)),
                            ],
                        );
                    }
                }
                let written = if t.gated.contains(&exit.index) { 0 } else { seg_len };
                t.monitor.observe(written, Some(&exit.signals));
                let sat = t.monitor.saturation();
                self.stats.saturation_milli.set((sat * 1e3).round() as u64);
                // Segment residency span on the lane's timeline:
                // admission / previous exit -> this exit. With packed
                // lanes this is what draws the paper's diagonal.
                if tracing {
                    let name = if exit.index < t.total_prompt {
                        "prefill_segment"
                    } else {
                        "decode_segment"
                    };
                    trace::complete(
                        name,
                        t.tr.last_span_us,
                        lane,
                        vec![
                            ("trace", Value::Num(t.tr.id as f64)),
                            ("id", Value::Num(t.wire_id as f64)),
                            ("segment", Value::Num(exit.index as f64)),
                        ],
                    );
                    t.tr.last_span_us = trace::now_us();
                }
                let pulled = t.pulled;
                let wire_id = t.wire_id;
                let (driver, ticket, tr) = (&mut t.driver, &t.ticket, &mut t.tr);
                let action = driver.on_exit(exit.index, &exit.logits, sat, &mut |ev| {
                    if let Event::Token { pos, .. } = &ev {
                        let token_at = Instant::now();
                        match tr.last_token_at {
                            None => engine_stats.ttft.observe(token_at.duration_since(pulled)),
                            Some(prev) => engine_stats
                                .inter_token
                                .observe(token_at.duration_since(prev)),
                        }
                        tr.last_token_at = Some(token_at);
                        if tracing {
                            trace::record(TraceEvent {
                                name: "decode_token",
                                ts_us: trace::now_us(),
                                dur_us: 0,
                                tid: lane,
                                args: vec![
                                    ("trace", Value::Num(tr.id as f64)),
                                    ("id", Value::Num(wire_id as f64)),
                                    ("pos", Value::Num(*pos as f64)),
                                ],
                            });
                        }
                    }
                    emit(ticket, ev)
                });
                let hand_off = match action {
                    ExitAction::Wait => Ok(()),
                    ExitAction::Feed(seg) => {
                        let fed = session.append_segment(exit.id, seg);
                        // The just-appended decode segment is the next
                        // checkpoint boundary.
                        if fed.is_ok() && checkpoint {
                            let _ = session.capture_after(exit.id, exit.index + 1);
                        }
                        fed
                    }
                    ExitAction::Finish => session.finish_stream(exit.id),
                };
                if let Err(e) = hand_off {
                    // Scheduler invariant violation — fail this request
                    // loudly, keep serving the others.
                    session.cancel(exit.id);
                    let t = tickets.remove(&exit.id).expect("present above");
                    emit(&t.ticket, Event::Error { error: e });
                }
            }

            // Completions.
            while let Some(out) = session.pop_completed() {
                let t = tickets.remove(&out.id).expect("completed request has a ticket");
                let latency = t.pulled.elapsed();
                self.stats.requests.inc();
                self.stats.diagonal_runs.inc();
                self.stats.packed_requests.inc();
                self.stats.tokens.add(t.prompt_tokens as u64);
                self.stats.generated_tokens.add(t.driver.generated.len() as u64);
                self.stats.latency.observe(latency);
                if t.tr.started_us != 0 && trace::enabled() {
                    trace::complete(
                        "request",
                        t.tr.started_us,
                        t.tr.lane,
                        vec![
                            ("trace", Value::Num(t.tr.id as f64)),
                            ("id", Value::Num(t.wire_id as f64)),
                            ("prompt_tokens", Value::Num(t.prompt_tokens as f64)),
                            ("generated", Value::Num(t.driver.generated.len() as f64)),
                            ("reused_segments", Value::Num(t.reused as f64)),
                        ],
                    );
                }
                let (resume_token, final_state) = self.retain_final(
                    &t.handle,
                    &t.blocks,
                    t.total_prompt,
                    &t.driver,
                    out.final_state,
                );
                let resp = Response {
                    id: t.wire_id,
                    greedy_tail: t.driver.last_greedy,
                    generated: t.driver.generated,
                    logits: t.want_logits.then_some(out.logits),
                    reused_segments: t.reused,
                    segments_skipped: t.gated.len(),
                    overflow_routed: t.routed,
                    saturation: t.monitor.saturation(),
                    resume_token,
                    final_state,
                    mode_used: ExecMode::Diagonal,
                    stats: out.stats,
                    latency,
                    trace: t.wire_trace,
                };
                emit(&t.ticket, Event::Done { stats: Box::new(resp) });
            }
        }
        Ok(())
    }

    /// Route one pulled job: pack it, run it single-shot, or reject it.
    /// Returns true iff the job was packed into the wavefront (false =
    /// completed inline: rejected, or executed single-shot).
    fn admit<T, F>(
        &mut self,
        (req, ticket): (GenerateRequest, T),
        session: &mut WavefrontSession,
        tickets: &mut HashMap<u64, ServeTicket<T>>,
        next_key: &mut u64,
        emit: &mut F,
    ) -> bool
    where
        F: FnMut(&T, Event),
    {
        if let Err(e) = self.validate(&req) {
            emit(&ticket, Event::Error { error: e });
            return false;
        }
        // Queue wait: front-end enqueue stamp -> this admission. The
        // histogram is always on (atomics only); the span is back-dated
        // to the enqueue time so it abuts the admit span in the trace.
        let tr_id = span_trace_id(&req);
        let admit_start_us = if tr_id != 0 && trace::enabled() { trace::now_us() } else { 0 };
        if let Some(wait) = req.enqueued.map(|e| e.elapsed()) {
            self.stats.queue_wait.observe(wait);
            if admit_start_us != 0 {
                let wait_us = wait.as_micros() as u64;
                trace::record(TraceEvent {
                    name: "queue_wait",
                    ts_us: admit_start_us.saturating_sub(wait_us),
                    dur_us: wait_us,
                    tid: TID_CONTROL,
                    args: vec![
                        ("trace", Value::Num(tr_id as f64)),
                        ("id", Value::Num(req.id as f64)),
                    ],
                });
            }
        }
        // Chunked routing happens at admission on the serving path — a
        // mid-flight re-route would throw away packed wavefront work
        // the single-shot path can afford to waste. The fill predictor
        // has no energy signal, so only clearly overflowing prompts
        // (over 1.5x capacity) are rewritten to their best window.
        let mut req = req;
        let mut routed = false;
        if req.overflow == OverflowPolicy::Chunked
            && req.resume.is_none()
            && quality::predicted_saturation(self.backend.config(), req.prompt.len())
                > quality::CHUNK_THRESHOLD
        {
            let (seg, window_segs) = {
                let cfg = self.backend.config();
                (cfg.seg, (cfg.phi_dim / cfg.seg).max(1))
            };
            let chunks = quality::segment_tokens(&req.prompt, seg);
            let (lo, hi) = quality::choose_window(&chunks, window_segs);
            let mut prompt: Vec<u32> =
                chunks[lo..hi].iter().flat_map(|b| b.iter().copied()).collect();
            prompt.extend_from_slice(chunks.last().expect("validated: nonempty prompt"));
            req.prompt = prompt;
            // The window is already capacity-sized: clear the policy so
            // no downstream path re-routes the rewritten prompt.
            req.overflow = OverflowPolicy::Off;
            routed = true;
            self.stats.overflow_routed.inc();
            if admit_start_us != 0 {
                trace::record(TraceEvent {
                    name: "overflow_route",
                    ts_us: trace::now_us(),
                    dur_us: 0,
                    tid: TID_CONTROL,
                    args: vec![
                        ("trace", Value::Num(tr_id as f64)),
                        ("id", Value::Num(req.id as f64)),
                    ],
                });
            }
        }
        let n_segments = req.prompt.len().div_ceil(self.backend.config().seg);
        // Generation always packs into the wavefront (decode is
        // diagonal-native; Auto's prefill-length heuristic does not
        // apply) unless the client explicitly forced another mode.
        let resolved = if req.max_new_tokens > 0
            && !matches!(req.mode, Some(ExecMode::Sequential) | Some(ExecMode::FullAttention))
        {
            ExecMode::Diagonal
        } else {
            self.resolve_mode(&req, n_segments)
        };
        match resolved {
            ExecMode::Diagonal => {
                let lookup_start_us = if admit_start_us != 0 { trace::now_us() } else { 0 };
                let plan = match self.plan_prefill(&req) {
                    Ok(p) => p,
                    Err(e) => {
                        emit(&ticket, Event::Error { error: e });
                        return false;
                    }
                };
                if lookup_start_us != 0 {
                    trace::complete(
                        "cache_lookup",
                        lookup_start_us,
                        TID_CONTROL,
                        vec![
                            ("trace", Value::Num(tr_id as f64)),
                            ("id", Value::Num(req.id as f64)),
                            ("hit", Value::Bool(plan.reused > 0)),
                            ("reused_segments", Value::Num(plan.reused as f64)),
                        ],
                    );
                }
                // Selection gates, decided before submission from token
                // ids alone (deterministic across schedules/threads).
                let gates: HashSet<usize> = if req.overflow == OverflowPolicy::Select {
                    quality::plan_selection(&plan.segments)
                        .iter()
                        .enumerate()
                        .filter(|(_, &skip)| skip)
                        .map(|(i, _)| plan.reused + i)
                        .collect()
                } else {
                    HashSet::new()
                };
                // Gated boundary states never enter the shared prefix
                // store (they embody this request's policy).
                let blocks = if gates.is_empty() { plan.blocks } else { None };
                let key = *next_key;
                *next_key += 1;
                let handle = req.handle();
                let submitted = match plan.snapshot {
                    Some(snap) => {
                        session.submit_stream_resumed(key, snap, plan.segments, req.want_logits)
                    }
                    None => session.submit_stream(key, plan.segments, req.want_logits),
                };
                match submitted {
                    Ok(()) => {
                        if !gates.is_empty() {
                            self.stats.segments_skipped.add(gates.len() as u64);
                            let _ = session.set_memory_gates(key, gates.clone());
                        }
                        // Snapshot capture (infallible right after a
                        // successful submit): prompt-boundary states
                        // feed the prefix store, the final state feeds
                        // conversation save/resume — including a
                        // mid-flight {"cmd": "save"}.
                        if handle.save_requested() || self.cache.is_some() {
                            let _ = session.capture_final(key);
                        }
                        if self.cache.is_some() && blocks.is_some() {
                            for idx in plan.reused..plan.total_prompt {
                                let _ = session.capture_after(key, idx);
                            }
                        }
                        // Checkpointed requests (shard failover) want
                        // EVERY prompt boundary regardless of the cache;
                        // targets are a set, so overlap is harmless.
                        // Decode boundaries are armed per-append in the
                        // exit loop.
                        if req.checkpoint {
                            for idx in plan.reused..plan.total_prompt {
                                let _ = session.capture_after(key, idx);
                            }
                        }
                        if req.max_new_tokens == 0 {
                            // Pure prefill: close the stream up front so
                            // the lane hands over the moment the last
                            // segment is injected (maximal ramp overlap,
                            // exactly the pre-decode packing behavior).
                            let _ = session.finish_stream(key);
                        }
                        let pulled = Instant::now();
                        let mut monitor = MemoryMonitor::new(self.backend.config());
                        if plan.reused > 0 {
                            // History reused from a prefix hit / resume
                            // already occupies memory.
                            monitor.observe(plan.reused * self.backend.config().seg, None);
                        }
                        tickets.insert(
                            key,
                            ServeTicket {
                                driver: GenDriver::new(&req, plan.total_prompt),
                                handle,
                                deadline: req.deadline.map(|d| pulled + d),
                                wire_id: req.id,
                                prompt_tokens: req.prompt.len(),
                                want_logits: req.want_logits,
                                blocks,
                                total_prompt: plan.total_prompt,
                                reused: plan.reused,
                                pulled,
                                ticket,
                                checkpoint: req.checkpoint,
                                monitor,
                                gated: gates,
                                routed,
                                tr: ReqTrace {
                                    id: tr_id,
                                    started_us: admit_start_us,
                                    last_span_us: if admit_start_us != 0 {
                                        trace::now_us()
                                    } else {
                                        0
                                    },
                                    lane: TID_CONTROL,
                                    last_token_at: None,
                                },
                                wire_trace: req.trace,
                            },
                        );
                        if admit_start_us != 0 {
                            trace::complete(
                                "admit",
                                admit_start_us,
                                TID_CONTROL,
                                vec![
                                    ("trace", Value::Num(tr_id as f64)),
                                    ("id", Value::Num(req.id as f64)),
                                    ("reused_segments", Value::Num(plan.reused as f64)),
                                    ("routed", Value::Bool(routed)),
                                ],
                            );
                        }
                        true
                    }
                    Err(e) => {
                        emit(&ticket, Event::Error { error: e });
                        false
                    }
                }
            }
            // Sequential / full-attention overrides run single-shot
            // between wavefront iterations (at most one per iteration —
            // see the admission loop), streaming their events inline.
            // Inline GENERATION is refused: a sequential decode of
            // max_new_tokens would monopolize the engine thread for its
            // whole run, stalling every packed request and freezing
            // cancel/deadline polling. (Prefill-only overrides stay
            // bounded by their prompt, as before.)
            _ => {
                if req.max_new_tokens > 0 {
                    self.stats.rejected.inc();
                    emit(
                        &ticket,
                        Event::Error {
                            error: Error::Request(
                                "generation on the serving path requires diagonal mode \
                                 (a non-diagonal decode would stall the shared wavefront); \
                                 drop the mode override, or use process()/generate() directly"
                                    .into(),
                            ),
                        },
                    );
                    return false;
                }
                let _ = self.generate(&req, |ev| emit(&ticket, ev));
                false
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};

    fn engine(mode: ExecMode) -> InferenceEngine<NativeBackend> {
        let cfg = crate::model::tests::test_config();
        let params = Params::random(&cfg, 9);
        InferenceEngine::new(NativeBackend::new(cfg, params), mode)
    }

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| (i * 13 + 1) % 64).collect()
    }

    /// Fold an event stream back into the old `(ticket, Result)` shape
    /// most assertions want.
    fn collect_terminal(got: &mut Vec<(u64, Result<Response>)>, ticket: u64, ev: Event) {
        match ev {
            Event::Done { stats } => got.push((ticket, Ok(*stats))),
            Event::Error { error } => got.push((ticket, Err(error))),
            _ => {}
        }
    }

    #[test]
    fn process_roundtrip_and_stats() {
        let mut e = engine(ExecMode::Diagonal);
        let resp = e.process(&GenerateRequest::new(1, toks(24))).unwrap();
        assert_eq!(resp.mode_used, ExecMode::Diagonal);
        assert_eq!(resp.greedy_tail.len(), e.config().seg);
        assert!(resp.generated.is_empty());
        assert_eq!(e.stats.requests.get(), 1);
        assert_eq!(e.stats.diagonal_runs.get(), 1);
        assert!(resp.latency > Duration::ZERO);
        assert!(e.stats.mean_group() > 0.0);
        assert!(e.stats.occupancy.value() > 0.0);
    }

    #[test]
    fn diagonal_equals_sequential_through_engine() {
        let mut e1 = engine(ExecMode::Diagonal);
        let mut e2 = engine(ExecMode::Sequential);
        let mut r = GenerateRequest::new(2, toks(8 * 4));
        r.want_logits = true;
        let a = e1.process(&r).unwrap();
        let b = e2.process(&r).unwrap();
        let (la, lb) = (a.logits.unwrap(), b.logits.unwrap());
        assert_eq!(la, lb); // native backend: bit-exact
    }

    #[test]
    fn streamed_generation_events_are_consistent() {
        // 2-segment prompt + 12 new tokens (seg = 8): one full decode
        // segment is fed back, then 4 more tokens come from its exit.
        let mut e = engine(ExecMode::Diagonal);
        let req = GenerateRequest::new(3, toks(8 * 2)).generate(12);
        let mut tokens = Vec::new();
        let mut segments = Vec::new();
        let mut done = None;
        e.generate(&req, |ev| match ev {
            Event::Token { pos, token } => tokens.push((pos, token)),
            Event::SegmentDone { index, .. } => segments.push(index),
            Event::Done { stats } => done = Some(*stats),
            Event::Error { error } => panic!("unexpected error: {error}"),
            _ => {}
        })
        .unwrap();
        let done = done.expect("terminal Done event");
        assert_eq!(done.generated.len(), 12);
        assert_eq!(tokens.len(), 12);
        for (i, (pos, tok)) in tokens.iter().enumerate() {
            assert_eq!(*pos, i, "token positions are contiguous");
            assert_eq!(*tok, done.generated[i], "stream matches the aggregate");
        }
        // 2 prompt exits + 1 fed decode segment exit, in order.
        assert_eq!(segments, vec![0, 1, 2]);
        assert_eq!(done.stats.segments, 3);
        assert_eq!(e.stats.generated_tokens.get(), 12);
    }

    #[test]
    fn generation_identical_across_schedules() {
        // The decode recurrence is schedule-invariant: diagonal
        // in-wavefront decode == sequential decode, bit for bit.
        let mut e1 = engine(ExecMode::Diagonal);
        let mut e2 = engine(ExecMode::Sequential);
        let mut req = GenerateRequest::new(4, toks(8 * 3)).generate(20);
        req.want_logits = true;
        let a = e1.process(&req).unwrap();
        let b = e2.process(&req).unwrap();
        assert_eq!(a.generated, b.generated);
        assert_eq!(a.greedy_tail, b.greedy_tail);
        assert_eq!(a.logits.unwrap(), b.logits.unwrap());
    }

    #[test]
    fn auto_mode_respects_policy() {
        let mut e = engine(ExecMode::Auto).with_policy(FallbackPolicy::MinSegments(3));
        let short = e.process(&GenerateRequest::new(3, toks(8))).unwrap();
        assert_eq!(short.mode_used, ExecMode::Sequential);
        let long = e.process(&GenerateRequest::new(4, toks(8 * 5))).unwrap();
        assert_eq!(long.mode_used, ExecMode::Diagonal);
        assert_eq!(e.stats.sequential_runs.get(), 1);
        assert_eq!(e.stats.diagonal_runs.get(), 1);
    }

    #[test]
    fn rejects_empty_oversized_and_bad_sampling() {
        let mut e = engine(ExecMode::Diagonal).with_max_tokens(16);
        assert!(e.process(&GenerateRequest::new(5, vec![])).is_err());
        assert!(e.process(&GenerateRequest::new(6, toks(17))).is_err());
        // prompt + decode budget together exceed the limit
        assert!(e.process(&GenerateRequest::new(7, toks(10)).generate(7)).is_err());
        let bad = GenerateRequest::new(8, toks(8)).with_sampling(SamplingParams {
            temperature: -0.5,
            ..Default::default()
        });
        assert!(e.process(&bad).is_err());
        assert_eq!(e.stats.rejected.get(), 4);
    }

    #[test]
    fn calibration_produces_policy() {
        let mut e = engine(ExecMode::Auto);
        let cal = e.calibrate(2).unwrap();
        assert!(cal.grouped_step_s > 0.0);
        assert!(cal.single_step_s > 0.0);
        // native backend: grouped(L) ~= L * single, so diagonal should
        // win for large S but the crossover is finite
        assert!(cal.crossover_segments() > 0);
    }

    #[test]
    fn full_attention_mode() {
        let mut e = engine(ExecMode::FullAttention);
        let resp = e.process(&GenerateRequest::new(7, toks(12))).unwrap();
        assert_eq!(resp.mode_used, ExecMode::FullAttention);
        assert_eq!(e.stats.full_attn_runs.get(), 1);
        assert_eq!(resp.greedy_tail.len(), 12); // per-token logits
        // Generation is segment-recurrent; full attention refuses it.
        assert!(e.process(&GenerateRequest::new(8, toks(12)).generate(4)).is_err());
    }

    #[test]
    fn full_attention_does_not_dilute_wavefront_stats() {
        // A full-attention run executes no wavefront slots; it must not
        // add launches (which would drag mean_group toward zero) nor
        // touch the occupancy ratio.
        let mut e = engine(ExecMode::Diagonal);
        e.process(&GenerateRequest::new(1, toks(24))).unwrap();
        let launches_before = e.stats.launches.get();
        let occ_before = e.stats.occupancy.parts();
        let mg_before = e.stats.mean_group();
        assert!(launches_before > 0 && mg_before > 0.0);

        let mut r = GenerateRequest::new(2, toks(12));
        r.mode = Some(ExecMode::FullAttention);
        e.process(&r).unwrap();
        assert_eq!(e.stats.full_attn_runs.get(), 1);
        assert_eq!(e.stats.launches.get(), launches_before);
        assert_eq!(e.stats.occupancy.parts(), occ_before);
        assert_eq!(e.stats.mean_group(), mg_before);
        // ...while request-level counters still advance.
        assert_eq!(e.stats.requests.get(), 2);
        let js = e.stats.to_json().to_json();
        assert!(js.contains("\"full_attn_runs\":1"), "{js}");
        assert!(js.contains("\"cancelled\":0"), "{js}");
        assert!(js.contains("\"generated_tokens\""), "{js}");
    }

    #[test]
    fn per_request_mode_override() {
        let mut e = engine(ExecMode::Diagonal);
        let mut r = GenerateRequest::new(8, toks(16));
        r.mode = Some(ExecMode::Sequential);
        let resp = e.process(&r).unwrap();
        assert_eq!(resp.mode_used, ExecMode::Sequential);
    }

    #[test]
    fn pre_cancelled_request_never_runs() {
        let mut e = engine(ExecMode::Diagonal);
        let req = GenerateRequest::new(9, toks(8 * 4)).generate(64);
        req.handle().cancel();
        let err = e.process(&req).unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err}");
        assert_eq!(e.stats.cancelled.get(), 1);
        assert_eq!(e.stats.requests.get(), 0);
    }

    #[test]
    fn zero_deadline_expires() {
        let mut e = engine(ExecMode::Diagonal);
        let req =
            GenerateRequest::new(10, toks(8 * 4)).generate(64).with_deadline(Duration::ZERO);
        let err = e.process(&req).unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
        assert_eq!(e.stats.cancelled.get(), 1);
    }

    #[test]
    fn serve_queue_packs_and_is_bitexact() {
        // Push a burst of diagonal requests plus one sequential
        // override, close the queue, drain: every response must
        // bit-match the single-shot path, and the packed aggregate must
        // beat the solo mean_group.
        let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(16);
        for i in 0..4u64 {
            let mut r = GenerateRequest::new(i, toks(8 * (2 + i as usize)));
            r.want_logits = true;
            queue.push((r, i)).unwrap();
        }
        let mut seq_override = GenerateRequest::new(9, toks(16));
        seq_override.mode = Some(ExecMode::Sequential);
        seq_override.want_logits = true;
        queue.push((seq_override, 9)).unwrap();
        queue.push((GenerateRequest::new(10, vec![]), 10)).unwrap(); // rejected
        queue.close();

        let mut e = engine(ExecMode::Diagonal).with_lanes(2);
        let mut got: Vec<(u64, Result<Response>)> = Vec::new();
        e.serve_queue(&queue, |t, ev| collect_terminal(&mut got, *t, ev)).unwrap();
        assert_eq!(got.len(), 6);

        let mut reference = engine(ExecMode::Sequential);
        for (ticket, resp) in got {
            if ticket == 10 {
                assert!(resp.is_err());
                continue;
            }
            let resp = resp.unwrap();
            assert_eq!(resp.id, ticket);
            let mut r = GenerateRequest::new(
                ticket,
                toks(if ticket == 9 { 16 } else { 8 * (2 + ticket as usize) }),
            );
            r.want_logits = true;
            let want = reference.process(&r).unwrap();
            assert_eq!(resp.logits.unwrap(), want.logits.unwrap(), "request {ticket}");
        }
        assert_eq!(e.stats.packed_requests.get(), 4);
        assert_eq!(e.stats.sequential_runs.get(), 1);
        assert_eq!(e.stats.rejected.get(), 1);
        assert_eq!(e.stats.requests.get(), 5);
        // Packing must beat the best solo diagonal mean_group of these
        // requests (largest S here is 5 segments, L = 3).
        let solo_best = (5.0 * 3.0) / (5.0 + 3.0 - 1.0);
        assert!(
            e.stats.mean_group() > solo_best,
            "packed mean_group {} vs solo best {solo_best}",
            e.stats.mean_group()
        );
    }

    #[test]
    fn serve_queue_routes_generation_to_the_wavefront() {
        let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
        // Explicit sequential override WITH a decode budget: refused —
        // an inline decode would stall the shared wavefront.
        let mut seq_gen = GenerateRequest::new(0, toks(16)).generate(8);
        seq_gen.mode = Some(ExecMode::Sequential);
        queue.push((seq_gen, 0)).unwrap();
        // Auto + short prompt would resolve sequential for prefill, but
        // generation always packs as diagonal.
        let auto_gen = GenerateRequest::new(1, toks(8)).generate(8);
        queue.push((auto_gen, 1)).unwrap();
        queue.close();

        let mut e = engine(ExecMode::Auto).with_policy(FallbackPolicy::MinSegments(3));
        let mut got: Vec<(u64, Result<Response>)> = Vec::new();
        e.serve_queue(&queue, |t, ev| collect_terminal(&mut got, *t, ev)).unwrap();
        got.sort_by_key(|(t, _)| *t);
        assert_eq!(got.len(), 2);
        let err = got[0].1.as_ref().unwrap_err();
        assert!(err.to_string().contains("diagonal"), "{err}");
        let resp = got[1].1.as_ref().unwrap();
        assert_eq!(resp.mode_used, ExecMode::Diagonal);
        assert_eq!(resp.generated.len(), 8);
    }

    #[test]
    fn serve_queue_streams_generation_and_cancels() {
        // Two generating requests; one is cancelled mid-stream via its
        // handle. The survivor's continuation must match its solo run
        // exactly, and the victim must terminate with Event::Error.
        let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
        let victim = GenerateRequest::new(0, toks(8 * 2)).generate(8 * 64);
        let victim_handle = victim.handle();
        queue.push((victim, 0)).unwrap();
        let survivor = GenerateRequest::new(1, toks(8 * 3)).generate(20);
        queue.push((survivor, 1)).unwrap();
        queue.close();

        let mut e = engine(ExecMode::Diagonal).with_lanes(2);
        let mut survivor_tokens: Vec<u32> = Vec::new();
        let mut victim_err = None;
        let mut survivor_done = None;
        e.serve_queue(&queue, |t, ev| match (*t, ev) {
            (0, Event::Token { pos, .. }) => {
                if pos >= 4 {
                    victim_handle.cancel();
                }
            }
            (0, Event::Error { error }) => victim_err = Some(error),
            (1, Event::Token { token, .. }) => survivor_tokens.push(token),
            (1, Event::Done { stats }) => survivor_done = Some(*stats),
            _ => {}
        })
        .unwrap();

        let victim_err = victim_err.expect("victim must terminate with an error");
        assert!(victim_err.to_string().contains("cancelled"), "{victim_err}");
        assert_eq!(e.stats.cancelled.get(), 1);

        let done = survivor_done.expect("survivor completes");
        assert_eq!(done.generated.len(), 20);
        assert_eq!(survivor_tokens, done.generated);
        let solo = engine(ExecMode::Diagonal)
            .process(&GenerateRequest::new(1, toks(8 * 3)).generate(20))
            .unwrap();
        assert_eq!(done.generated, solo.generated, "cancel must not perturb the survivor");
    }

    #[test]
    fn serve_queue_pooled_backend_bitexact_and_counts_workers() {
        // Same weights as `engine()` (seed 9) but a 3-thread cell pool:
        // responses must bit-match the single-threaded sequential path,
        // and the worker-utilization counters must be live and sane.
        let cfg = crate::model::tests::test_config();
        let backend =
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 9)).with_threads(3);
        let mut e = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(2);

        let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
        for i in 0..3u64 {
            let mut r = GenerateRequest::new(i, toks(8 * (2 + i as usize)));
            r.want_logits = true;
            queue.push((r, i)).unwrap();
        }
        queue.close();
        let mut got: Vec<(u64, Result<Response>)> = Vec::new();
        e.serve_queue(&queue, |t, ev| collect_terminal(&mut got, *t, ev)).unwrap();

        let mut reference = engine(ExecMode::Sequential);
        for (ticket, resp) in got {
            let resp = resp.unwrap();
            let mut r = GenerateRequest::new(ticket, toks(8 * (2 + ticket as usize)));
            r.want_logits = true;
            let want = reference.process(&r).unwrap();
            assert_eq!(resp.logits.unwrap(), want.logits.unwrap(), "request {ticket}");
        }

        assert_eq!(e.stats.workers.get(), 3);
        assert!(e.stats.pool_cells.get() > 0, "pool must have executed cells");
        let (busy, cap) = e.stats.worker_busy.parts();
        assert!(busy <= cap, "busy {busy} > capacity {cap}");
        let js = e.stats.to_json().to_json();
        assert!(js.contains("\"workers\":3"), "{js}");
        assert!(js.contains("worker_utilization"), "{js}");

        // The kernel-tier counters must have seen this engine's GEMMs:
        // serving ran real matmuls, so the flop/time deltas are nonzero
        // and the derived throughput is finite and positive.
        assert!(e.stats.kernel_flops.get() > 0, "{js}");
        assert!(e.stats.kernel_ns.get() > 0, "{js}");
        assert!(e.stats.kernel_gflops() > 0.0 && e.stats.kernel_gflops().is_finite());
        assert!(js.contains("kernel_gflops"), "{js}");
        assert!(js.contains("\"matmul_f32\":"), "per-kernel breakdown missing: {js}");
    }

    #[test]
    fn serve_queue_exits_on_close_when_empty() {
        let queue: RequestQueue<(GenerateRequest, ())> = RequestQueue::new(4);
        queue.close();
        let mut e = engine(ExecMode::Diagonal);
        e.serve_queue(&queue, |_, _| panic!("no jobs were queued")).unwrap();
    }

    fn bits(ts: &[Tensor]) -> Vec<Vec<u32>> {
        ts.iter().map(|t| t.data().iter().map(|x| x.to_bits()).collect()).collect()
    }

    #[test]
    fn prefix_cache_hit_is_bitexact_and_counted() {
        // Two prompts sharing a 3-segment prefix: the second request
        // reuses the cached prefix, computes strictly fewer cells, and
        // its computed logits bit-match the cold oracle's tail.
        let shared = toks(8 * 3);
        let mut tail_a = shared.clone();
        tail_a.extend(toks(8).iter().map(|t| (t + 1) % 64));
        let mut tail_b = shared.clone();
        tail_b.extend(toks(8 * 2).iter().map(|t| (t + 2) % 64));

        let mut cold = engine(ExecMode::Diagonal);
        let mut warm = engine(ExecMode::Diagonal).with_cache_bytes(1 << 22);

        let mut ra = GenerateRequest::new(1, tail_a.clone());
        ra.want_logits = true;
        let cold_a = cold.process(&ra).unwrap();
        let warm_a = warm.process(&ra).unwrap();
        assert_eq!(warm_a.reused_segments, 0, "empty cache: no reuse");
        assert_eq!(warm.stats.cache_hits.get(), 0);
        assert!(warm.stats.cache_bytes.get() > 0, "prefill snapshots were inserted");
        assert_eq!(bits(&warm_a.logits.unwrap()), bits(&cold_a.logits.unwrap()));

        let mut rb = GenerateRequest::new(2, tail_b.clone());
        rb.want_logits = true;
        let cold_b = cold.process(&rb).unwrap();
        let warm_b = warm.process(&rb).unwrap();
        assert_eq!(warm_b.reused_segments, 3, "shared prefix reused");
        assert_eq!(warm.stats.cache_hits.get(), 1);
        assert_eq!(warm.stats.cache_hit_segments.get(), 3);
        assert!(
            warm_b.stats.cells < cold_b.stats.cells,
            "hit request must execute strictly fewer prefill cells"
        );
        assert_eq!(warm_b.stats.segments, 2, "only the tail was computed");
        // Computed logits == the oracle's logits for those segments.
        let cold_logits = cold_b.logits.unwrap();
        assert_eq!(bits(&warm_b.logits.unwrap()), bits(&cold_logits[3..]));
        assert_eq!(warm_b.greedy_tail, cold_b.greedy_tail);
        let js = warm.stats.to_json().to_json();
        assert!(js.contains("\"cache_hits\":1"), "{js}");
        assert!(js.contains("\"cache_hit_segments\":3"), "{js}");
    }

    #[test]
    fn cache_hit_generation_matches_cold_run() {
        // Generation after a prefix hit: the continuation must be
        // token-identical to the cold full-prefill run.
        let prompt = toks(8 * 4);
        let mut cold = engine(ExecMode::Diagonal);
        let mut warm = engine(ExecMode::Diagonal).with_cache_bytes(1 << 22);
        let req = GenerateRequest::new(1, prompt.clone()).generate(20);
        let want = cold.process(&req).unwrap();

        warm.process(&GenerateRequest::new(2, prompt.clone())).unwrap(); // seed the store
        let got = warm.process(&GenerateRequest::new(3, prompt).generate(20)).unwrap();
        assert_eq!(got.reused_segments, 3, "all but the last prompt segment reused");
        assert_eq!(got.generated, want.generated);
        assert_eq!(got.greedy_tail, want.greedy_tail);
    }

    #[test]
    fn save_and_resume_token_roundtrip_is_exact() {
        // Turn 1 saves; turn 2 resumes with only the new tokens. The
        // result must bit-match one straight-through run over the
        // concatenated history — with zero history prefill in turn 2.
        let turn1 = toks(8 * 2);
        let extra: Vec<u32> = toks(8).iter().map(|t| (t + 3) % 64).collect();

        let mut e = engine(ExecMode::Diagonal);
        // generate(16): the first decode segment (8 tokens) is fed back
        // into the recurrence, the second is emitted without being fed
        // — so the saved state covers 2 prompt + 1 decode segments.
        let r1 = GenerateRequest::new(7, turn1.clone()).generate(16).with_save();
        let resp1 = e.process(&r1).unwrap();
        let token = resp1.resume_token.expect("engine assigned a resume token");
        assert!(resp1.final_state.is_some());
        assert_eq!(e.saved_conversations(), 1);

        let mut turn2 = extra.clone();
        let mut r2 = GenerateRequest::new(8, turn2.clone()).generate(8).resume_token(token);
        r2.want_logits = true;
        let resp2 = e.process(&r2).unwrap();
        assert_eq!(resp2.reused_segments, 3, "2 prompt + 1 fed decode segment of history");

        // Oracle: full recompute over turn-1 history + turn-2 tokens.
        let mut full = turn1;
        full.extend_from_slice(&resp1.generated[..8]); // the fed decode segment
        full.append(&mut turn2);
        let mut oracle = engine(ExecMode::Sequential);
        let mut ro = GenerateRequest::new(9, full).generate(8);
        ro.want_logits = true;
        let want = oracle.process(&ro).unwrap();
        assert_eq!(resp2.generated, want.generated);
        let want_logits = want.logits.unwrap();
        let got_logits = resp2.logits.unwrap();
        assert_eq!(bits(&got_logits), bits(&want_logits[3..]));
    }

    #[test]
    fn resume_guards() {
        let mut e = engine(ExecMode::Diagonal);
        let err = e
            .process(&GenerateRequest::new(1, toks(8)).resume_token(42))
            .unwrap_err();
        assert!(err.to_string().contains("resume token"), "{err}");

        // Full attention has no recurrent state to seed.
        let snap_src = e.process(&GenerateRequest::new(2, toks(8)).with_save()).unwrap();
        let snap = snap_src.final_state.unwrap();
        let mut r = GenerateRequest::new(3, toks(8)).resume_snapshot(snap);
        r.mode = Some(ExecMode::FullAttention);
        assert!(e.process(&r).is_err());
    }

    #[test]
    fn saved_conversations_are_bounded_and_tokens_unique() {
        // Two saves on a max_saved(1) engine: distinct tokens, the
        // older conversation is dropped (counted as an eviction) and
        // resuming it fails loudly while the newer one still works.
        let mut e = engine(ExecMode::Diagonal).with_max_saved(1);
        let t1 = e
            .process(&GenerateRequest::new(1, toks(8)).with_save())
            .unwrap()
            .resume_token
            .unwrap();
        let t2 = e
            .process(&GenerateRequest::new(2, toks(16)).with_save())
            .unwrap()
            .resume_token
            .unwrap();
        assert_ne!(t1, t2, "tokens never alias");
        assert_eq!(e.saved_conversations(), 1);
        assert_eq!(e.stats.cache_evictions.get(), 1);
        assert!(e.process(&GenerateRequest::new(3, toks(8)).resume_token(t1)).is_err());
        assert!(e.process(&GenerateRequest::new(4, toks(8)).resume_token(t2)).is_ok());
    }

    #[test]
    fn saturation_is_monitored_and_reported() {
        let mut e = engine(ExecMode::Diagonal);
        let resp = e.process(&GenerateRequest::new(1, toks(8 * 4))).unwrap();
        assert!(resp.saturation > 0.0 && resp.saturation <= 1.0, "{}", resp.saturation);
        assert_eq!(resp.segments_skipped, 0);
        assert!(!resp.overflow_routed);
        assert!(e.stats.saturation_milli.get() > 0);
        let js = e.stats.to_json().to_json();
        assert!(js.contains("\"saturation\":"), "{js}");
        assert!(js.contains("\"segments_skipped\":0"), "{js}");
        assert!(js.contains("\"overflow_routed\":0"), "{js}");
    }

    #[test]
    fn segment_done_events_carry_saturation() {
        let mut e = engine(ExecMode::Diagonal);
        let mut sats = Vec::new();
        e.generate(&GenerateRequest::new(2, toks(8 * 3)), |ev| {
            if let Event::SegmentDone { saturation, .. } = ev {
                sats.push(saturation);
            }
        })
        .unwrap();
        assert_eq!(sats.len(), 3);
        assert!(sats.iter().all(|&s| s > 0.0 && s <= 1.0), "{sats:?}");
    }

    /// A prompt whose middle is repeated filler and whose final (query)
    /// segment repeats the head: selection must gate filler only.
    fn selective_prompt() -> Vec<u32> {
        let head = toks(8);
        let mut prompt = head.clone();
        for _ in 0..3 {
            prompt.extend(std::iter::repeat(60u32).take(8));
        }
        prompt.extend(head);
        prompt
    }

    #[test]
    fn selection_gates_memory_and_reports_counts() {
        let mut e = engine(ExecMode::Diagonal);
        let req = GenerateRequest::new(1, selective_prompt())
            .with_overflow(OverflowPolicy::Select);
        let resp = e.process(&req).unwrap();
        assert!(resp.segments_skipped > 0, "repeated filler must be gated");
        assert_eq!(e.stats.segments_skipped.get(), resp.segments_skipped as u64);

        let mut off = engine(ExecMode::Diagonal);
        let resp_off = off.process(&GenerateRequest::new(1, selective_prompt())).unwrap();
        assert_eq!(resp_off.segments_skipped, 0);
        assert_eq!(off.stats.segments_skipped.get(), 0);
    }

    #[test]
    fn selection_is_schedule_invariant() {
        // The gated recurrence is one definition with two
        // implementations: the session's save/restore around the
        // grouped step, and the sequential loop's skipped writeback.
        // Same gates, bit-identical logits.
        let mk = |mode| {
            let mut req = GenerateRequest::new(5, selective_prompt())
                .with_overflow(OverflowPolicy::Select)
                .with_mode(mode);
            req.want_logits = true;
            req
        };
        let a = engine(ExecMode::Auto).process(&mk(ExecMode::Diagonal)).unwrap();
        let b = engine(ExecMode::Auto).process(&mk(ExecMode::Sequential)).unwrap();
        assert!(a.segments_skipped > 0);
        assert_eq!(a.segments_skipped, b.segments_skipped);
        assert_eq!(bits(&a.logits.unwrap()), bits(&b.logits.unwrap()));
    }

    #[test]
    fn chunked_policy_reroutes_overflowing_prompts() {
        // phi_dim = 48 in the test config: a 64-segment prompt (512
        // tokens) is >> 1.5x capacity, so the fill predictor alone
        // routes it to a capacity-sized window (6 segments + query).
        let mut e = engine(ExecMode::Diagonal);
        let req =
            GenerateRequest::new(6, toks(8 * 64)).with_overflow(OverflowPolicy::Chunked);
        let resp = e.process(&req).unwrap();
        assert!(resp.overflow_routed);
        assert_eq!(e.stats.overflow_routed.get(), 1);
        assert!(
            resp.stats.segments < 64,
            "routed run must execute a reduced window, got {}",
            resp.stats.segments
        );

        let full = engine(ExecMode::Diagonal)
            .process(&GenerateRequest::new(6, toks(8 * 64)))
            .unwrap();
        assert!(!full.overflow_routed);
        assert_eq!(full.stats.segments, 64);
    }

    #[test]
    fn serve_queue_applies_overflow_policies() {
        let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
        queue
            .push((
                GenerateRequest::new(0, selective_prompt())
                    .with_overflow(OverflowPolicy::Select),
                0,
            ))
            .unwrap();
        queue
            .push((
                GenerateRequest::new(1, toks(8 * 64)).with_overflow(OverflowPolicy::Chunked),
                1,
            ))
            .unwrap();
        queue.close();
        let mut e = engine(ExecMode::Diagonal).with_lanes(2);
        let mut got: Vec<(u64, Result<Response>)> = Vec::new();
        e.serve_queue(&queue, |t, ev| collect_terminal(&mut got, *t, ev)).unwrap();
        got.sort_by_key(|(t, _)| *t);
        let select = got[0].1.as_ref().unwrap();
        assert!(select.segments_skipped > 0);
        assert!(!select.overflow_routed);
        let chunked = got[1].1.as_ref().unwrap();
        assert!(chunked.overflow_routed);
        assert!(chunked.saturation > 0.0);
        assert_eq!(chunked.stats.segments, 7, "6-segment window + query segment");
        assert!(e.stats.segments_skipped.get() > 0);
        assert_eq!(e.stats.overflow_routed.get(), 1);
        assert!(e.stats.saturation_milli.get() > 0);
    }
}
