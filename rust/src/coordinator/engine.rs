//! The inference engine: request in, logits/decode out.

use std::time::{Duration, Instant};

use crate::config::{ExecMode, ModelConfig};
use crate::coordinator::fallback::{Calibration, FallbackPolicy};
use crate::error::{Error, Result};
use crate::metrics::{Counter, Histogram};
use crate::scheduler::{Executor, RunStats, ScheduleMode, StepBackend};
use crate::tensor::Tensor;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Optional per-request mode override.
    pub mode: Option<ExecMode>,
    /// Return full logits (false = only the greedy tail tokens).
    pub want_logits: bool,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<u32>) -> Self {
        Self { id, tokens, mode: None, want_logits: false }
    }
}

/// What the engine returns.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Greedy (argmax) token per position of the FINAL segment.
    pub greedy_tail: Vec<usize>,
    /// Full per-segment logits if requested.
    pub logits: Option<Vec<Tensor>>,
    pub mode_used: ExecMode,
    pub stats: RunStats,
    pub latency: Duration,
}

/// Aggregate serving counters.
#[derive(Default)]
pub struct EngineStats {
    pub requests: Counter,
    pub rejected: Counter,
    pub diagonal_runs: Counter,
    pub sequential_runs: Counter,
    pub full_attn_runs: Counter,
    pub tokens: Counter,
    pub latency: Histogram,
}

/// Engine over any [`StepBackend`].
pub struct InferenceEngine<B: StepBackend> {
    backend: B,
    mode: ExecMode,
    policy: FallbackPolicy,
    max_request_tokens: usize,
    pub stats: EngineStats,
}

impl<B: StepBackend> InferenceEngine<B> {
    pub fn new(backend: B, mode: ExecMode) -> Self {
        Self {
            backend,
            mode,
            policy: FallbackPolicy::AlwaysDiagonal,
            max_request_tokens: 1 << 20,
            stats: EngineStats::default(),
        }
    }

    pub fn with_policy(mut self, policy: FallbackPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_max_tokens(mut self, max: usize) -> Self {
        self.max_request_tokens = max;
        self
    }

    pub fn config(&self) -> &ModelConfig {
        self.backend.config()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Measure per-step costs and install a calibrated fallback policy
    /// (used by `mode = Auto`; see Table 9).
    pub fn calibrate(&mut self, iters: usize) -> Result<Calibration> {
        let cfg = self.backend.config().clone();
        let l = cfg.n_layers;
        let x = Tensor::zeros(&[l, cfg.seg_total, cfg.d_model]);
        let a = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
        let z = Tensor::zeros(&[l, cfg.phi_dim]);
        let mask = vec![1.0; l];
        // warmup + timed grouped steps
        self.backend.grouped_step(&x, &a, &z, &mask)?;
        let t0 = Instant::now();
        for _ in 0..iters.max(1) {
            self.backend.grouped_step(&x, &a, &z, &mask)?;
        }
        let grouped_step_s = t0.elapsed().as_secs_f64() / iters.max(1) as f64;

        let x1 = x.index0(0);
        let a1 = a.index0(0);
        let z1 = z.index0(0);
        self.backend.single_step(0, &x1, &a1, &z1)?;
        let t0 = Instant::now();
        for _ in 0..iters.max(1) {
            self.backend.single_step(0, &x1, &a1, &z1)?;
        }
        let single_step_s = t0.elapsed().as_secs_f64() / iters.max(1) as f64;

        let cal = Calibration { grouped_step_s, single_step_s, n_layers: l };
        self.policy = FallbackPolicy::Calibrated(cal);
        Ok(cal)
    }

    fn resolve_mode(&self, req: &Request, n_segments: usize) -> ExecMode {
        let mode = req.mode.unwrap_or(self.mode);
        match mode {
            ExecMode::Auto => {
                if self.policy.use_diagonal(n_segments) {
                    ExecMode::Diagonal
                } else {
                    ExecMode::Sequential
                }
            }
            m => m,
        }
    }

    /// Execute one request synchronously.
    pub fn process(&mut self, req: &Request) -> Result<Response> {
        if req.tokens.is_empty() {
            self.stats.rejected.inc();
            return Err(Error::Request("empty token sequence".into()));
        }
        if req.tokens.len() > self.max_request_tokens {
            self.stats.rejected.inc();
            return Err(Error::Request(format!(
                "request of {} tokens exceeds limit {}",
                req.tokens.len(),
                self.max_request_tokens
            )));
        }
        let cfg = self.backend.config();
        let n_segments = req.tokens.len().div_ceil(cfg.seg);
        let mode = self.resolve_mode(req, n_segments);
        let started = Instant::now();

        let (logits, stats, mode_used) = match mode {
            ExecMode::FullAttention => {
                self.stats.full_attn_runs.inc();
                let t0 = Instant::now();
                let out = self.backend.full_attn(&req.tokens)?;
                let stats = RunStats {
                    mode_diagonal: false,
                    segments: 1,
                    launches: 1,
                    cells: 0,
                    padded_cells: 0,
                    wall: t0.elapsed(),
                    tokens: req.tokens.len(),
                };
                (vec![out], stats, ExecMode::FullAttention)
            }
            ExecMode::Diagonal => {
                self.stats.diagonal_runs.inc();
                let out = Executor::new(&mut self.backend, ScheduleMode::Diagonal)
                    .run(&req.tokens)?;
                (out.logits, out.stats, ExecMode::Diagonal)
            }
            ExecMode::Sequential => {
                self.stats.sequential_runs.inc();
                let out = Executor::new(&mut self.backend, ScheduleMode::Sequential)
                    .run(&req.tokens)?;
                (out.logits, out.stats, ExecMode::Sequential)
            }
            ExecMode::Auto => unreachable!("resolved above"),
        };

        let greedy_tail = logits.last().map(|t| t.argmax_rows()).unwrap_or_default();
        let latency = started.elapsed();
        self.stats.requests.inc();
        self.stats.tokens.add(req.tokens.len() as u64);
        self.stats.latency.observe(latency);
        Ok(Response {
            id: req.id,
            greedy_tail,
            logits: req.want_logits.then_some(logits),
            mode_used,
            stats,
            latency,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};

    fn engine(mode: ExecMode) -> InferenceEngine<NativeBackend> {
        let cfg = crate::model::tests::test_config();
        let params = Params::random(&cfg, 9);
        InferenceEngine::new(NativeBackend::new(cfg, params), mode)
    }

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| (i * 13 + 1) % 64).collect()
    }

    #[test]
    fn process_roundtrip_and_stats() {
        let mut e = engine(ExecMode::Diagonal);
        let resp = e.process(&Request::new(1, toks(24))).unwrap();
        assert_eq!(resp.mode_used, ExecMode::Diagonal);
        assert_eq!(resp.greedy_tail.len(), e.config().seg);
        assert_eq!(e.stats.requests.get(), 1);
        assert_eq!(e.stats.diagonal_runs.get(), 1);
        assert!(resp.latency > Duration::ZERO);
    }

    #[test]
    fn diagonal_equals_sequential_through_engine() {
        let mut e1 = engine(ExecMode::Diagonal);
        let mut e2 = engine(ExecMode::Sequential);
        let mut r = Request::new(2, toks(8 * 4));
        r.want_logits = true;
        let a = e1.process(&r).unwrap();
        let b = e2.process(&r).unwrap();
        let (la, lb) = (a.logits.unwrap(), b.logits.unwrap());
        assert_eq!(la, lb); // native backend: bit-exact
    }

    #[test]
    fn auto_mode_respects_policy() {
        let mut e = engine(ExecMode::Auto).with_policy(FallbackPolicy::MinSegments(3));
        let short = e.process(&Request::new(3, toks(8))).unwrap();
        assert_eq!(short.mode_used, ExecMode::Sequential);
        let long = e.process(&Request::new(4, toks(8 * 5))).unwrap();
        assert_eq!(long.mode_used, ExecMode::Diagonal);
        assert_eq!(e.stats.sequential_runs.get(), 1);
        assert_eq!(e.stats.diagonal_runs.get(), 1);
    }

    #[test]
    fn rejects_empty_and_oversized() {
        let mut e = engine(ExecMode::Diagonal).with_max_tokens(16);
        assert!(e.process(&Request::new(5, vec![])).is_err());
        assert!(e.process(&Request::new(6, toks(17))).is_err());
        assert_eq!(e.stats.rejected.get(), 2);
    }

    #[test]
    fn calibration_produces_policy() {
        let mut e = engine(ExecMode::Auto);
        let cal = e.calibrate(2).unwrap();
        assert!(cal.grouped_step_s > 0.0);
        assert!(cal.single_step_s > 0.0);
        // native backend: grouped(L) ~= L * single, so diagonal should
        // win for large S but the crossover is finite
        assert!(cal.crossover_segments() > 0);
    }

    #[test]
    fn full_attention_mode() {
        let mut e = engine(ExecMode::FullAttention);
        let resp = e.process(&Request::new(7, toks(12))).unwrap();
        assert_eq!(resp.mode_used, ExecMode::FullAttention);
        assert_eq!(e.stats.full_attn_runs.get(), 1);
        assert_eq!(resp.greedy_tail.len(), 12); // per-token logits
    }

    #[test]
    fn per_request_mode_override() {
        let mut e = engine(ExecMode::Diagonal);
        let mut r = Request::new(8, toks(16));
        r.mode = Some(ExecMode::Sequential);
        let resp = e.process(&r).unwrap();
        assert_eq!(resp.mode_used, ExecMode::Sequential);
    }
}
