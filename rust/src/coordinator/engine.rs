//! The inference engine: request in, logits/decode out.
//!
//! Two execution paths share one backend:
//!
//! * [`InferenceEngine::process`] — the single-shot path: one request,
//!   one executor run (any [`ExecMode`]);
//! * [`InferenceEngine::serve_queue`] — the serving path: a continuous
//!   drain loop that packs every diagonal-mode request into one
//!   persistent [`WavefrontSession`], admitting new requests from the
//!   [`RequestQueue`] *between wavefront iterations* and completing them
//!   out of submission order. Sequential / full-attention requests (rare
//!   overrides) still run single-shot between iterations.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{ExecMode, ModelConfig};
use crate::coordinator::fallback::{Calibration, FallbackPolicy};
use crate::coordinator::queue::RequestQueue;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::metrics::{Counter, Gauge, Histogram, Ratio};
use crate::scheduler::{Executor, RunStats, ScheduleMode, StepBackend, WavefrontSession};
use crate::tensor::Tensor;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Optional per-request mode override.
    pub mode: Option<ExecMode>,
    /// Return full logits (false = only the greedy tail tokens).
    pub want_logits: bool,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<u32>) -> Self {
        Self { id, tokens, mode: None, want_logits: false }
    }
}

/// What the engine returns.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Greedy (argmax) token per position of the FINAL segment.
    pub greedy_tail: Vec<usize>,
    /// Full per-segment logits if requested.
    pub logits: Option<Vec<Tensor>>,
    pub mode_used: ExecMode,
    pub stats: RunStats,
    pub latency: Duration,
}

/// Aggregate serving counters (shared: the engine thread writes, any
/// connection thread may snapshot via [`InferenceEngine::stats_handle`]).
#[derive(Default)]
pub struct EngineStats {
    pub requests: Counter,
    pub rejected: Counter,
    pub diagonal_runs: Counter,
    pub sequential_runs: Counter,
    pub full_attn_runs: Counter,
    /// Requests served inside a packed wavefront session (subset of
    /// `diagonal_runs`).
    pub packed_requests: Counter,
    pub tokens: Counter,
    pub latency: Histogram,
    /// Grouped/step launches across all runs and sessions.
    pub launches: Counter,
    /// Wavefront occupancy: active cells / slot-steps, across all runs
    /// and sessions. The denominator-minus-numerator is the padded-cell
    /// count the ISSUE's utilization work drives down.
    pub occupancy: Ratio,
    /// Backend worker threads executing cells (1 = inline execution;
    /// set by `serve_queue` from the backend's pool).
    pub workers: Gauge,
    /// Cells the serving loop executed on pool workers (subset of
    /// `active_cells`: single-cell wavefront tips run inline).
    pub pool_cells: Counter,
    /// Worker utilization while serving: summed worker busy-time over
    /// `threads x` serving wall-time, both in microseconds. The
    /// parallel-execution analog of `occupancy` — occupancy says how
    /// full the wavefront's *slots* are, this says how busy the
    /// *threads* executing them are.
    pub worker_busy: Ratio,
}

impl EngineStats {
    /// Mean active cells per launch (the paper's utilization proxy,
    /// aggregated over everything this engine executed).
    pub fn mean_group(&self) -> f64 {
        let launches = self.launches.get();
        if launches == 0 {
            0.0
        } else {
            self.occupancy.parts().0 as f64 / launches as f64
        }
    }

    /// Padded slot-steps accumulated so far. (`Ratio` snapshots are
    /// ordered so active <= slots; saturate anyway — a stats read must
    /// never panic the serving path.)
    pub fn padded_cells(&self) -> u64 {
        let (active, slots) = self.occupancy.parts();
        slots.saturating_sub(active)
    }

    /// Snapshot as a JSON object (the server's `{"cmd": "stats"}` body).
    /// Derived fields are computed from ONE occupancy snapshot so they
    /// stay mutually consistent under concurrent engine writes.
    pub fn to_json(&self) -> Value {
        let (active, slots) = self.occupancy.parts();
        let launches = self.launches.get();
        let mean_group =
            if launches == 0 { 0.0 } else { active as f64 / launches as f64 };
        let occupancy = if slots == 0 { 0.0 } else { active as f64 / slots as f64 };
        Value::obj(vec![
            ("requests", Value::Num(self.requests.get() as f64)),
            ("rejected", Value::Num(self.rejected.get() as f64)),
            ("diagonal_runs", Value::Num(self.diagonal_runs.get() as f64)),
            ("sequential_runs", Value::Num(self.sequential_runs.get() as f64)),
            ("full_attn_runs", Value::Num(self.full_attn_runs.get() as f64)),
            ("packed_requests", Value::Num(self.packed_requests.get() as f64)),
            ("tokens", Value::Num(self.tokens.get() as f64)),
            ("launches", Value::Num(launches as f64)),
            ("active_cells", Value::Num(active as f64)),
            ("slot_steps", Value::Num(slots as f64)),
            ("padded_cells", Value::Num(slots.saturating_sub(active) as f64)),
            ("mean_group", Value::Num(mean_group)),
            ("occupancy", Value::Num(occupancy)),
            ("workers", Value::Num(self.workers.get() as f64)),
            ("pool_cells", Value::Num(self.pool_cells.get() as f64)),
            ("pool_busy_ms", Value::Num(self.worker_busy.parts().0 as f64 / 1e3)),
            ("worker_utilization", Value::Num(self.worker_busy.value())),
            ("latency_ms_mean", Value::Num(self.latency.mean().as_secs_f64() * 1e3)),
            ("latency_ms_p50", Value::Num(self.latency.quantile(0.5).as_secs_f64() * 1e3)),
            ("latency_ms_p90", Value::Num(self.latency.quantile(0.9).as_secs_f64() * 1e3)),
            ("latency_ms_p99", Value::Num(self.latency.quantile(0.99).as_secs_f64() * 1e3)),
        ])
    }
}

/// Ticket held for a request in the packed wavefront.
struct PackedTicket<T> {
    ticket: T,
    wire_id: u64,
    want_logits: bool,
    pulled: Instant,
}

/// Engine over any [`StepBackend`].
pub struct InferenceEngine<B: StepBackend> {
    backend: B,
    mode: ExecMode,
    policy: FallbackPolicy,
    max_request_tokens: usize,
    /// Slot lanes per wavefront session (`serve_queue`); 1 = pure
    /// stream packing, >1 additionally batches lanes per launch on
    /// backends whose grouped program is lane-batched (native). The
    /// current single-lane HLO artifacts execute extra lanes serially —
    /// correct but not faster — so leave this at 1 there.
    lanes: usize,
    pub stats: Arc<EngineStats>,
}

impl<B: StepBackend> InferenceEngine<B> {
    pub fn new(backend: B, mode: ExecMode) -> Self {
        Self {
            backend,
            mode,
            policy: FallbackPolicy::AlwaysDiagonal,
            max_request_tokens: 1 << 20,
            lanes: 1,
            stats: Arc::new(EngineStats::default()),
        }
    }

    pub fn with_policy(mut self, policy: FallbackPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_max_tokens(mut self, max: usize) -> Self {
        self.max_request_tokens = max;
        self
    }

    pub fn with_lanes(mut self, lanes: usize) -> Self {
        self.lanes = lanes.max(1);
        self
    }

    pub fn config(&self) -> &ModelConfig {
        self.backend.config()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Shared handle to the live counters (snapshot-safe from other
    /// threads while the engine runs).
    pub fn stats_handle(&self) -> Arc<EngineStats> {
        self.stats.clone()
    }

    /// Measure per-step costs and install a calibrated fallback policy
    /// (used by `mode = Auto`; see Table 9).
    pub fn calibrate(&mut self, iters: usize) -> Result<Calibration> {
        let cfg = self.backend.config().clone();
        let l = cfg.n_layers;
        let x = Tensor::zeros(&[l, cfg.seg_total, cfg.d_model]);
        let a = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
        let z = Tensor::zeros(&[l, cfg.phi_dim]);
        let mask = vec![1.0; l];
        // warmup + timed grouped steps
        self.backend.grouped_step(&x, &a, &z, &mask)?;
        let t0 = Instant::now();
        for _ in 0..iters.max(1) {
            self.backend.grouped_step(&x, &a, &z, &mask)?;
        }
        let grouped_step_s = t0.elapsed().as_secs_f64() / iters.max(1) as f64;

        let x1 = x.index0(0);
        let a1 = a.index0(0);
        let z1 = z.index0(0);
        self.backend.single_step(0, &x1, &a1, &z1)?;
        let t0 = Instant::now();
        for _ in 0..iters.max(1) {
            self.backend.single_step(0, &x1, &a1, &z1)?;
        }
        let single_step_s = t0.elapsed().as_secs_f64() / iters.max(1) as f64;

        let cal = Calibration { grouped_step_s, single_step_s, n_layers: l };
        self.policy = FallbackPolicy::Calibrated(cal);
        Ok(cal)
    }

    fn resolve_mode(&self, req: &Request, n_segments: usize) -> ExecMode {
        let mode = req.mode.unwrap_or(self.mode);
        match mode {
            ExecMode::Auto => {
                if self.policy.use_diagonal(n_segments) {
                    ExecMode::Diagonal
                } else {
                    ExecMode::Sequential
                }
            }
            m => m,
        }
    }

    /// Reject obviously bad requests before they reach a scheduler.
    fn validate(&self, req: &Request) -> Result<()> {
        if req.tokens.is_empty() {
            self.stats.rejected.inc();
            return Err(Error::Request("empty token sequence".into()));
        }
        if req.tokens.len() > self.max_request_tokens {
            self.stats.rejected.inc();
            return Err(Error::Request(format!(
                "request of {} tokens exceeds limit {}",
                req.tokens.len(),
                self.max_request_tokens
            )));
        }
        Ok(())
    }

    /// Fold one finished run into the aggregate utilization counters.
    fn record_run(&self, stats: &RunStats) {
        self.stats.launches.add(stats.launches);
        self.stats
            .occupancy
            .add(stats.slot_steps - stats.padded_cells, stats.slot_steps);
    }

    /// Execute one request synchronously (single-shot path).
    pub fn process(&mut self, req: &Request) -> Result<Response> {
        self.validate(req)?;
        let cfg = self.backend.config();
        let n_segments = req.tokens.len().div_ceil(cfg.seg);
        let mode = self.resolve_mode(req, n_segments);
        let started = Instant::now();

        let (logits, stats, mode_used) = match mode {
            ExecMode::FullAttention => {
                self.stats.full_attn_runs.inc();
                let t0 = Instant::now();
                let out = self.backend.full_attn(&req.tokens)?;
                let stats = RunStats {
                    mode_diagonal: false,
                    segments: 1,
                    launches: 1,
                    cells: 0,
                    slot_steps: 0,
                    padded_cells: 0,
                    wall: t0.elapsed(),
                    tokens: req.tokens.len(),
                };
                (vec![out], stats, ExecMode::FullAttention)
            }
            ExecMode::Diagonal => {
                self.stats.diagonal_runs.inc();
                let out = Executor::new(&mut self.backend, ScheduleMode::Diagonal)
                    .run(&req.tokens)?;
                (out.logits, out.stats, ExecMode::Diagonal)
            }
            ExecMode::Sequential => {
                self.stats.sequential_runs.inc();
                let out = Executor::new(&mut self.backend, ScheduleMode::Sequential)
                    .run(&req.tokens)?;
                (out.logits, out.stats, ExecMode::Sequential)
            }
            ExecMode::Auto => unreachable!("resolved above"),
        };

        let greedy_tail = logits.last().map(|t| t.argmax_rows()).unwrap_or_default();
        let latency = started.elapsed();
        self.stats.requests.inc();
        self.stats.tokens.add(req.tokens.len() as u64);
        self.stats.latency.observe(latency);
        self.record_run(&stats);
        Ok(Response {
            id: req.id,
            greedy_tail,
            logits: req.want_logits.then_some(logits),
            mode_used,
            stats,
            latency,
        })
    }

    /// Continuous-batching drain loop (the serving path).
    ///
    /// Pulls `(Request, ticket)` jobs from `queue`, packs every
    /// diagonal-mode request into one persistent [`WavefrontSession`]
    /// (lanes from [`with_lanes`](Self::with_lanes)), and invokes
    /// `complete` with each ticket as its response is ready — generally
    /// OUT of submission order, since short requests overtake long ones.
    /// Admission happens between wavefront iterations: the queue is
    /// polled non-blockingly while requests are in flight and blockingly
    /// when the wavefront is empty. Returns when the queue is closed and
    /// everything in flight has completed.
    ///
    /// # Examples
    ///
    /// Drain a burst of requests through one packed wavefront (the
    /// ticket type `T` is whatever the caller needs to route replies —
    /// the TCP server uses an `mpsc::Sender`, this example an index):
    ///
    /// ```no_run
    /// use diagonal_batching::config::{ExecMode, Manifest};
    /// use diagonal_batching::coordinator::{InferenceEngine, Request, RequestQueue};
    /// use diagonal_batching::model::{NativeBackend, Params};
    ///
    /// let manifest = Manifest::load("artifacts/manifest.json").unwrap();
    /// let entry = manifest.model("tiny").unwrap();
    /// let backend =
    ///     NativeBackend::new(entry.config.clone(), Params::load(&manifest, "tiny").unwrap());
    /// let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(2);
    ///
    /// let queue: RequestQueue<(Request, usize)> = RequestQueue::new(8);
    /// for i in 0..4u64 {
    ///     let tokens: Vec<u32> = (0..128).map(|t| t % 100).collect();
    ///     queue.push((Request::new(i, tokens), i as usize)).unwrap();
    /// }
    /// queue.close(); // a live server keeps pushing instead
    /// engine.serve_queue(&queue, |ticket, resp| {
    ///     println!("request #{ticket}: {:?}", resp.map(|r| r.stats.launches));
    /// }).unwrap();
    /// // p50/p90/p99 of everything served, as `{"cmd": "stats"}` reports:
    /// let stats = engine.stats_handle();
    /// println!("p99 {:?}", stats.latency.quantile(0.99));
    /// ```
    pub fn serve_queue<T, F>(
        &mut self,
        queue: &RequestQueue<(Request, T)>,
        mut complete: F,
    ) -> Result<()>
    where
        F: FnMut(T, Result<Response>),
    {
        let mut session = WavefrontSession::new(self.backend.config().clone(), self.lanes);
        let mut tickets: HashMap<u64, PackedTicket<T>> = HashMap::new();
        // Session keys are engine-local: wire ids may collide across
        // connections, in-flight keys must not.
        let mut next_key: u64 = 0;
        let mut last = session.stats();
        let mut last_ws = self.backend.worker_stats();
        let mut last_wall = Instant::now();
        self.stats.workers.set(last_ws.threads as u64);
        loop {
            // Admission. Block only when the wavefront is empty; keep
            // the backlog shallow so queue backpressure stays honest.
            if session.is_idle() {
                match queue.pop() {
                    None => break, // closed and drained
                    Some(job) => {
                        self.admit(job, &mut session, &mut tickets, &mut next_key, &mut complete);
                    }
                }
            }
            while session.backlog() < session.lanes() {
                match queue.try_pop() {
                    Some(job) => {
                        let packed = self.admit(
                            job,
                            &mut session,
                            &mut tickets,
                            &mut next_key,
                            &mut complete,
                        );
                        // A non-diagonal job was executed single-shot
                        // inline; bound that to one per wavefront
                        // iteration so in-flight packed requests are
                        // never stalled behind an unbounded run of
                        // sequential overrides.
                        if !packed {
                            break;
                        }
                    }
                    None => break,
                }
            }

            // One wavefront iteration.
            if let Err(e) = session.step(&mut self.backend) {
                let msg = e.to_string();
                for (_, t) in tickets.drain() {
                    complete(
                        t.ticket,
                        Err(Error::Schedule(format!("wavefront aborted: {msg}"))),
                    );
                }
                return Err(e);
            }

            // Aggregate utilization: session-level deltas (per-request
            // windows overlap, so they cannot be summed). Recorded
            // BEFORE the completion callbacks fire, so a client that
            // queries stats right after its reply sees its own
            // launches/occupancy included.
            let now = session.stats();
            self.stats.launches.add(now.launches - last.launches);
            self.stats.occupancy.add(
                now.cells - last.cells,
                now.slot_steps - last.slot_steps,
            );
            last = now;

            // Worker utilization: pool busy-time delta over the worker
            // capacity of this iteration's wall-time. Busy time is
            // measured inside the workers, so clamp to capacity — a
            // stats read must never trip the Ratio invariant.
            let ws = self.backend.worker_stats();
            let wall_us = last_wall.elapsed().as_micros() as u64;
            last_wall = Instant::now();
            let capacity_us = (ws.threads.max(1) as u64).saturating_mul(wall_us);
            let busy_us = ws.busy_us.saturating_sub(last_ws.busy_us).min(capacity_us);
            self.stats.pool_cells.add(ws.pool_cells.saturating_sub(last_ws.pool_cells));
            self.stats.worker_busy.add(busy_us, capacity_us);
            last_ws = ws;

            // Completions.
            while let Some(out) = session.pop_completed() {
                let t = tickets.remove(&out.id).expect("completed request has a ticket");
                let greedy_tail = out.logits.last().map(|l| l.argmax_rows()).unwrap_or_default();
                let latency = t.pulled.elapsed();
                self.stats.requests.inc();
                self.stats.diagonal_runs.inc();
                self.stats.packed_requests.inc();
                self.stats.tokens.add(out.stats.tokens as u64);
                self.stats.latency.observe(latency);
                complete(
                    t.ticket,
                    Ok(Response {
                        id: t.wire_id,
                        greedy_tail,
                        logits: t.want_logits.then_some(out.logits),
                        mode_used: ExecMode::Diagonal,
                        stats: out.stats,
                        latency,
                    }),
                );
            }
        }
        Ok(())
    }

    /// Route one pulled job: pack it, run it single-shot, or reject it.
    /// Returns true iff the job was packed into the wavefront (false =
    /// completed inline: rejected, or executed single-shot).
    fn admit<T, F>(
        &mut self,
        (req, ticket): (Request, T),
        session: &mut WavefrontSession,
        tickets: &mut HashMap<u64, PackedTicket<T>>,
        next_key: &mut u64,
        complete: &mut F,
    ) -> bool
    where
        F: FnMut(T, Result<Response>),
    {
        if let Err(e) = self.validate(&req) {
            complete(ticket, Err(e));
            return false;
        }
        let n_segments = req.tokens.len().div_ceil(self.backend.config().seg);
        match self.resolve_mode(&req, n_segments) {
            ExecMode::Diagonal => {
                let key = *next_key;
                *next_key += 1;
                match session.submit(key, &req.tokens) {
                    Ok(()) => {
                        tickets.insert(
                            key,
                            PackedTicket {
                                ticket,
                                wire_id: req.id,
                                want_logits: req.want_logits,
                                pulled: Instant::now(),
                            },
                        );
                        true
                    }
                    Err(e) => {
                        complete(ticket, Err(e));
                        false
                    }
                }
            }
            // Sequential / full-attention overrides run single-shot
            // between wavefront iterations (at most one per iteration —
            // see the admission loop).
            _ => {
                complete(ticket, self.process(&req));
                false
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};

    fn engine(mode: ExecMode) -> InferenceEngine<NativeBackend> {
        let cfg = crate::model::tests::test_config();
        let params = Params::random(&cfg, 9);
        InferenceEngine::new(NativeBackend::new(cfg, params), mode)
    }

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| (i * 13 + 1) % 64).collect()
    }

    #[test]
    fn process_roundtrip_and_stats() {
        let mut e = engine(ExecMode::Diagonal);
        let resp = e.process(&Request::new(1, toks(24))).unwrap();
        assert_eq!(resp.mode_used, ExecMode::Diagonal);
        assert_eq!(resp.greedy_tail.len(), e.config().seg);
        assert_eq!(e.stats.requests.get(), 1);
        assert_eq!(e.stats.diagonal_runs.get(), 1);
        assert!(resp.latency > Duration::ZERO);
        assert!(e.stats.mean_group() > 0.0);
        assert!(e.stats.occupancy.value() > 0.0);
    }

    #[test]
    fn diagonal_equals_sequential_through_engine() {
        let mut e1 = engine(ExecMode::Diagonal);
        let mut e2 = engine(ExecMode::Sequential);
        let mut r = Request::new(2, toks(8 * 4));
        r.want_logits = true;
        let a = e1.process(&r).unwrap();
        let b = e2.process(&r).unwrap();
        let (la, lb) = (a.logits.unwrap(), b.logits.unwrap());
        assert_eq!(la, lb); // native backend: bit-exact
    }

    #[test]
    fn auto_mode_respects_policy() {
        let mut e = engine(ExecMode::Auto).with_policy(FallbackPolicy::MinSegments(3));
        let short = e.process(&Request::new(3, toks(8))).unwrap();
        assert_eq!(short.mode_used, ExecMode::Sequential);
        let long = e.process(&Request::new(4, toks(8 * 5))).unwrap();
        assert_eq!(long.mode_used, ExecMode::Diagonal);
        assert_eq!(e.stats.sequential_runs.get(), 1);
        assert_eq!(e.stats.diagonal_runs.get(), 1);
    }

    #[test]
    fn rejects_empty_and_oversized() {
        let mut e = engine(ExecMode::Diagonal).with_max_tokens(16);
        assert!(e.process(&Request::new(5, vec![])).is_err());
        assert!(e.process(&Request::new(6, toks(17))).is_err());
        assert_eq!(e.stats.rejected.get(), 2);
    }

    #[test]
    fn calibration_produces_policy() {
        let mut e = engine(ExecMode::Auto);
        let cal = e.calibrate(2).unwrap();
        assert!(cal.grouped_step_s > 0.0);
        assert!(cal.single_step_s > 0.0);
        // native backend: grouped(L) ~= L * single, so diagonal should
        // win for large S but the crossover is finite
        assert!(cal.crossover_segments() > 0);
    }

    #[test]
    fn full_attention_mode() {
        let mut e = engine(ExecMode::FullAttention);
        let resp = e.process(&Request::new(7, toks(12))).unwrap();
        assert_eq!(resp.mode_used, ExecMode::FullAttention);
        assert_eq!(e.stats.full_attn_runs.get(), 1);
        assert_eq!(resp.greedy_tail.len(), 12); // per-token logits
    }

    #[test]
    fn per_request_mode_override() {
        let mut e = engine(ExecMode::Diagonal);
        let mut r = Request::new(8, toks(16));
        r.mode = Some(ExecMode::Sequential);
        let resp = e.process(&r).unwrap();
        assert_eq!(resp.mode_used, ExecMode::Sequential);
    }

    #[test]
    fn serve_queue_packs_and_is_bitexact() {
        // Push a burst of diagonal requests plus one sequential
        // override, close the queue, drain: every response must
        // bit-match the single-shot path, and the packed aggregate must
        // beat the solo mean_group.
        let queue: RequestQueue<(Request, u64)> = RequestQueue::new(16);
        for i in 0..4u64 {
            let mut r = Request::new(i, toks(8 * (2 + i as usize)));
            r.want_logits = true;
            queue.push((r, i)).unwrap();
        }
        let mut seq_override = Request::new(9, toks(16));
        seq_override.mode = Some(ExecMode::Sequential);
        seq_override.want_logits = true;
        queue.push((seq_override, 9)).unwrap();
        queue.push((Request::new(10, vec![]), 10)).unwrap(); // rejected
        queue.close();

        let mut e = engine(ExecMode::Diagonal).with_lanes(2);
        let mut got: Vec<(u64, Result<Response>)> = Vec::new();
        e.serve_queue(&queue, |ticket, resp| got.push((ticket, resp))).unwrap();
        assert_eq!(got.len(), 6);

        let mut reference = engine(ExecMode::Sequential);
        for (ticket, resp) in got {
            if ticket == 10 {
                assert!(resp.is_err());
                continue;
            }
            let resp = resp.unwrap();
            assert_eq!(resp.id, ticket);
            let mut r = Request::new(ticket, toks(if ticket == 9 { 16 } else { 8 * (2 + ticket as usize) }));
            r.want_logits = true;
            let want = reference.process(&r).unwrap();
            assert_eq!(resp.logits.unwrap(), want.logits.unwrap(), "request {ticket}");
        }
        assert_eq!(e.stats.packed_requests.get(), 4);
        assert_eq!(e.stats.sequential_runs.get(), 1);
        assert_eq!(e.stats.rejected.get(), 1);
        assert_eq!(e.stats.requests.get(), 5);
        // Packing must beat the best solo diagonal mean_group of these
        // requests (largest S here is 5 segments, L = 3).
        let solo_best = (5.0 * 3.0) / (5.0 + 3.0 - 1.0);
        assert!(
            e.stats.mean_group() > solo_best,
            "packed mean_group {} vs solo best {solo_best}",
            e.stats.mean_group()
        );
    }

    #[test]
    fn serve_queue_pooled_backend_bitexact_and_counts_workers() {
        // Same weights as `engine()` (seed 9) but a 3-thread cell pool:
        // responses must bit-match the single-threaded sequential path,
        // and the worker-utilization counters must be live and sane.
        let cfg = crate::model::tests::test_config();
        let backend =
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 9)).with_threads(3);
        let mut e = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(2);

        let queue: RequestQueue<(Request, u64)> = RequestQueue::new(8);
        for i in 0..3u64 {
            let mut r = Request::new(i, toks(8 * (2 + i as usize)));
            r.want_logits = true;
            queue.push((r, i)).unwrap();
        }
        queue.close();
        let mut got: Vec<(u64, Result<Response>)> = Vec::new();
        e.serve_queue(&queue, |ticket, resp| got.push((ticket, resp))).unwrap();

        let mut reference = engine(ExecMode::Sequential);
        for (ticket, resp) in got {
            let resp = resp.unwrap();
            let mut r = Request::new(ticket, toks(8 * (2 + ticket as usize)));
            r.want_logits = true;
            let want = reference.process(&r).unwrap();
            assert_eq!(resp.logits.unwrap(), want.logits.unwrap(), "request {ticket}");
        }

        assert_eq!(e.stats.workers.get(), 3);
        assert!(e.stats.pool_cells.get() > 0, "pool must have executed cells");
        let (busy, cap) = e.stats.worker_busy.parts();
        assert!(busy <= cap, "busy {busy} > capacity {cap}");
        let js = e.stats.to_json().to_json();
        assert!(js.contains("\"workers\":3"), "{js}");
        assert!(js.contains("worker_utilization"), "{js}");
    }

    #[test]
    fn serve_queue_exits_on_close_when_empty() {
        let queue: RequestQueue<(Request, ())> = RequestQueue::new(4);
        queue.close();
        let mut e = engine(ExecMode::Diagonal);
        e.serve_queue(&queue, |_, _| panic!("no jobs were queued")).unwrap();
    }
}
