//! Serving coordinator: the production wrapper around the executors.
//!
//! * [`engine`] — `InferenceEngine`: owns a backend, executes requests in
//!   any [`crate::config::ExecMode`], produces responses with stats;
//! * [`fallback`] — the Table 9 runtime policy ("in cases when diagonal
//!   batching is slower, we can fall back to the original inference
//!   algorithm at runtime"): calibration + per-request mode choice;
//! * [`queue`] — bounded FIFO request queue with backpressure (the
//!   paper's deployment point: one long-context request at a time
//!   saturates the device, so the queue is depth-limited and fair).

pub mod engine;
pub mod fallback;
pub mod queue;

pub use engine::{EngineStats, InferenceEngine, Request, Response};
pub use fallback::FallbackPolicy;
pub use queue::RequestQueue;
