//! Serving coordinator: the production wrapper around the schedulers.
//!
//! * [`engine`] — `InferenceEngine`: owns a backend; the API is a
//!   streaming generation lifecycle: a [`GenerateRequest`] (prompt +
//!   decode budget + sampling + optional deadline) produces a stream of
//!   [`Event`]s ending in `Done`/`Error`, cancellable via a
//!   [`RequestHandle`]. `generate`/`process` execute one request in any
//!   [`crate::config::ExecMode`]; `serve_queue` is the
//!   continuous-batching drain loop that packs concurrent diagonal-mode
//!   requests — prefill AND in-wavefront decode — into one persistent
//!   [`crate::scheduler::WavefrontSession`] and completes them out of
//!   submission order. With [`InferenceEngine::with_cache_bytes`] the
//!   engine also runs the memory-state cache ([`crate::cache`]):
//!   admissions reuse the longest cached prompt prefix (skipping its
//!   prefill bit-exactly) and completed conversations can be saved and
//!   resumed ([`GenerateRequest::resume`], `"save"`) without ever
//!   re-prefilling history;
//! * [`sampling`] — per-request token sampling (greedy by default,
//!   seeded temperature/top-k otherwise);
//! * [`fallback`] — the Table 9 runtime policy ("in cases when diagonal
//!   batching is slower, we can fall back to the original inference
//!   algorithm at runtime"): calibration + per-request mode choice;
//! * [`queue`] — bounded FIFO request queue with backpressure. Admission
//!   into the wavefront happens between iterations (`try_pop`), so a
//!   deep backlog applies queue-full backpressure instead of unbounded
//!   latency. The drain loop consumes any [`queue::JobSource`], so the
//!   gateway's weighted-fair scheduler
//!   ([`crate::gateway::FairScheduler`]) slots into the same seam.

pub mod engine;
pub mod fallback;
pub mod queue;
pub mod sampling;

pub use engine::{
    EngineStats, Event, GenerateRequest, InferenceEngine, RequestHandle, Response, ResumeFrom,
};
pub use fallback::FallbackPolicy;
pub use queue::{JobSource, RequestQueue};
pub use sampling::SamplingParams;
