//! Acceptance tests for the streaming generation lifecycle (ISSUE 4):
//!
//! * **Exact-recurrence decode** — a continuation generated inside the
//!   live wavefront bit-matches (`f32::to_bits`) running the same
//!   prompt + generated tokens through the sequential single-shot
//!   oracle;
//! * **Packed decode** — a multi-client generation burst achieves a
//!   higher aggregate `mean_group` than the best solo diagonal run
//!   (including the `L` ceiling a solo wavefront cannot exceed);
//! * **Cancellation** — mid-prefill and mid-decode evictions free the
//!   lane and leave every other in-flight request bit-exact;
//! * **Deadlines** — an expired request terminates with an error event
//!   while its neighbors complete.

use std::time::Duration;

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{
    Event, GenerateRequest, InferenceEngine, RequestQueue, SamplingParams,
};
use diagonal_batching::model::{NativeBackend, Params};

fn test_config() -> ModelConfig {
    ModelConfig {
        name: "gen-test".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 3,
        n_heads: 2,
        d_ff: 48,
        seg: 8,
        mem: 4,
        k_assoc: 8,
        dpfp_nu: 3,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 16,
        phi_dim: 48,
        seg_total: 12,
    }
}

fn engine(seed: u64, mode: ExecMode) -> InferenceEngine<NativeBackend> {
    let cfg = test_config();
    InferenceEngine::new(NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)), mode)
}

fn toks(n: usize, salt: u32) -> Vec<u32> {
    (0..n as u32).map(|i| (i * 7 + salt) % 64).collect()
}

fn bits(t: &diagonal_batching::tensor::Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

/// The headline acceptance: stream a generation through the diagonal
/// wavefront (ragged prompt tail included), then replay prompt + the
/// fed continuation through the sequential single-shot oracle — every
/// per-segment logits tensor must match to the bit.
#[test]
fn streamed_decode_bitmatches_sequential_oracle() {
    let cfg = test_config();
    let seg = cfg.seg;
    let prompt = toks(3 * seg - 2, 3); // ragged tail, pads to 3 segments
    let max_new = 2 * seg + 3; // 2 fed decode segments + 3 tokens off the last exit

    let mut req = GenerateRequest::new(1, prompt.clone()).generate(max_new);
    req.want_logits = true;
    let mut streamed_tokens = Vec::new();
    let mut e = engine(71, ExecMode::Diagonal);
    let mut done = None;
    e.generate(&req, |ev| match ev {
        Event::Token { pos, token } => {
            assert_eq!(pos, streamed_tokens.len(), "token positions are dense");
            streamed_tokens.push(token);
        }
        Event::Done { stats } => done = Some(*stats),
        Event::Error { error } => panic!("generation failed: {error}"),
        _ => {}
    })
    .unwrap();
    let resp = done.expect("terminal event");
    assert_eq!(resp.generated.len(), max_new);
    assert_eq!(resp.generated, streamed_tokens);

    // Reconstruct exactly what was fed: the padded prompt plus every
    // FULLY fed decode segment (the last 3 tokens were emitted off the
    // final exit without being fed back).
    let mut fed = prompt.clone();
    fed.resize(3 * seg, 0); // pad-token convention of segment_tokens
    fed.extend_from_slice(&resp.generated[..2 * seg]);

    let mut oracle_req = GenerateRequest::new(2, fed.clone());
    oracle_req.want_logits = true;
    let mut oracle = engine(71, ExecMode::Sequential);
    let want = oracle.process(&oracle_req).unwrap();

    let streamed_logits = resp.logits.expect("want_logits");
    let oracle_logits = want.logits.expect("want_logits");
    assert_eq!(streamed_logits.len(), 5, "3 prompt + 2 fed decode segments");
    assert_eq!(streamed_logits.len(), oracle_logits.len());
    for (i, (a, b)) in streamed_logits.iter().zip(&oracle_logits).enumerate() {
        assert_eq!(bits(a), bits(b), "segment {i} logits diverge from the oracle");
    }
    // The 3 trailing tokens are the argmax of the oracle's last segment.
    let tail: Vec<u32> =
        oracle_logits.last().unwrap().argmax_rows()[..3].iter().map(|&t| t as u32).collect();
    assert_eq!(&resp.generated[2 * seg..], &tail[..]);

    // And the diagonal single-shot run over the fed tokens agrees too.
    let mut diag_req = GenerateRequest::new(3, fed);
    diag_req.want_logits = true;
    let diag = engine(71, ExecMode::Diagonal).process(&diag_req).unwrap();
    for (a, b) in diag.logits.unwrap().iter().zip(&oracle_logits) {
        assert_eq!(bits(a), bits(b));
    }
}

/// Seeded non-greedy sampling is reproducible end to end, and its
/// continuation still bit-matches the oracle recurrence over the tokens
/// it actually produced.
#[test]
fn seeded_sampling_reproduces_and_stays_exact() {
    let sampling = SamplingParams { temperature: 0.9, top_k: 8, seed: 1234 };
    let req = GenerateRequest::new(1, toks(16, 5)).generate(20).with_sampling(sampling);
    let a = engine(72, ExecMode::Diagonal).process(&req).unwrap();
    let b = engine(72, ExecMode::Diagonal).process(&req).unwrap();
    assert_eq!(a.generated, b.generated, "same seed, same continuation");
    // The sampler consumes the same logits either schedule, so the
    // sequential path reproduces the identical sampled continuation.
    let c = engine(72, ExecMode::Sequential).process(&req).unwrap();
    assert_eq!(a.generated, c.generated);
}

/// The packing acceptance: a generation burst across many lanes beats
/// the best solo diagonal run's mean_group — and the `L` ceiling no
/// solo wavefront can exceed — while every continuation stays
/// bit-identical to its solo run.
#[test]
fn generation_burst_beats_best_solo_mean_group() {
    let cfg = test_config();
    let n_clients = 8u64;
    let lanes = 8;
    let max_new = 3 * cfg.seg;
    let prompt = |i: u64| toks(2 * cfg.seg, 10 + i as u32);

    // Solo baselines on identical weights.
    let mut best_solo = 0.0f64;
    let mut solo_generated = Vec::new();
    for i in 0..n_clients {
        let mut solo = engine(73, ExecMode::Diagonal);
        let resp = solo.process(&GenerateRequest::new(i, prompt(i)).generate(max_new)).unwrap();
        assert_eq!(resp.generated.len(), max_new);
        best_solo = best_solo.max(resp.stats.mean_group());
        solo_generated.push(resp.generated);
    }

    // The packed burst.
    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(n_clients as usize);
    for i in 0..n_clients {
        queue.push((GenerateRequest::new(i, prompt(i)).generate(max_new), i)).unwrap();
    }
    queue.close();
    let mut e = engine(73, ExecMode::Diagonal).with_lanes(lanes);
    let mut burst: Vec<Option<Vec<u32>>> = vec![None; n_clients as usize];
    e.serve_queue(&queue, |t, ev| match ev {
        Event::Done { stats } => burst[*t as usize] = Some(stats.generated.clone()),
        Event::Error { error } => panic!("request {t} failed: {error}"),
        _ => {}
    })
    .unwrap();

    for (i, got) in burst.iter().enumerate() {
        let got = got.as_ref().expect("completed");
        assert_eq!(got, &solo_generated[i], "request {i}: packed decode diverged");
    }

    let mg = e.stats.mean_group();
    let ceiling = cfg.n_layers as f64;
    assert!(
        mg > best_solo && mg > ceiling,
        "burst mean_group {mg:.3} must beat best solo {best_solo:.3} and the ceiling {ceiling}"
    );
    assert_eq!(e.stats.generated_tokens.get(), n_clients * max_new as u64);
}

/// Cancel a request while its prompt is still prefilling: the lane is
/// reclaimed and the other in-flight requests complete bit-exactly.
#[test]
fn cancel_mid_prefill_keeps_neighbors_exact() {
    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
    let victim = GenerateRequest::new(0, toks(8 * 40, 1)); // long prefill
    let handle = victim.handle();
    queue.push((victim, 0)).unwrap();
    let mut neighbor = GenerateRequest::new(1, toks(8 * 4, 2));
    neighbor.want_logits = true;
    queue.push((neighbor, 1)).unwrap();
    queue.close();

    let mut e = engine(74, ExecMode::Diagonal).with_lanes(2);
    let mut victim_failed = false;
    let mut neighbor_resp = None;
    e.serve_queue(&queue, |t, ev| match (*t, ev) {
        // First streamed partial result of the victim: still dozens of
        // prompt segments to go — cancel now, mid-prefill.
        (0, Event::SegmentDone { index, .. }) => {
            assert!(index < 40);
            handle.cancel();
        }
        (0, Event::Error { error }) => {
            assert!(error.to_string().contains("cancelled"), "{error}");
            victim_failed = true;
        }
        (0, Event::Done { .. }) => panic!("victim must not complete"),
        (1, Event::Done { stats }) => neighbor_resp = Some(*stats),
        (1, Event::Error { error }) => panic!("neighbor failed: {error}"),
        _ => {}
    })
    .unwrap();
    assert!(victim_failed);
    assert_eq!(e.stats.cancelled.get(), 1);

    let mut solo_req = GenerateRequest::new(1, toks(8 * 4, 2));
    solo_req.want_logits = true;
    let want = engine(74, ExecMode::Sequential).process(&solo_req).unwrap();
    let got = neighbor_resp.expect("neighbor completed");
    assert_eq!(got.logits.unwrap(), want.logits.unwrap(), "neighbor perturbed by eviction");
}

/// Cancel mid-decode: generation stops, the lane frees for a pending
/// request, and that late request's output is bit-exact.
#[test]
fn cancel_mid_decode_frees_lane_for_pending_request() {
    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
    let victim = GenerateRequest::new(0, toks(8, 1)).generate(8 * 512);
    let handle = victim.handle();
    queue.push((victim, 0)).unwrap();
    // Single lane: the late request can only run once the victim's
    // reserved lane is reclaimed by the cancel.
    let mut late = GenerateRequest::new(1, toks(8 * 3, 9));
    late.want_logits = true;
    queue.push((late, 1)).unwrap();
    queue.close();

    let mut e = engine(75, ExecMode::Diagonal).with_lanes(1);
    let mut late_resp = None;
    let mut victim_tokens = 0usize;
    e.serve_queue(&queue, |t, ev| match (*t, ev) {
        (0, Event::Token { pos, .. }) => {
            victim_tokens = pos + 1;
            if pos >= 10 {
                handle.cancel();
            }
        }
        (0, Event::Error { error }) => {
            assert!(error.to_string().contains("cancelled"), "{error}");
        }
        (0, Event::Done { .. }) => panic!("victim must not complete"),
        (1, Event::Done { stats }) => late_resp = Some(*stats),
        (1, Event::Error { error }) => panic!("late request failed: {error}"),
        _ => {}
    })
    .unwrap();
    assert!(victim_tokens >= 10, "victim was decoding when cancelled");

    let mut solo_req = GenerateRequest::new(1, toks(8 * 3, 9));
    solo_req.want_logits = true;
    let want = engine(75, ExecMode::Sequential).process(&solo_req).unwrap();
    assert_eq!(
        late_resp.expect("late request completed").logits.unwrap(),
        want.logits.unwrap(),
        "the reclaimed lane leaked state into the next request"
    );
}

/// A request with an immediate deadline is evicted with a deadline
/// error while its neighbor completes normally.
#[test]
fn deadline_eviction_in_packed_wavefront() {
    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
    queue
        .push((
            GenerateRequest::new(0, toks(8 * 4, 1))
                .generate(8 * 64)
                .with_deadline(Duration::ZERO),
            0,
        ))
        .unwrap();
    queue.push((GenerateRequest::new(1, toks(8 * 2, 2)).generate(8), 1)).unwrap();
    queue.close();

    let mut e = engine(76, ExecMode::Diagonal).with_lanes(2);
    let mut expired = false;
    let mut neighbor_done = false;
    e.serve_queue(&queue, |t, ev| match (*t, ev) {
        (0, Event::Error { error }) => {
            assert!(error.to_string().contains("deadline"), "{error}");
            expired = true;
        }
        (0, Event::Done { .. }) => panic!("expired request must not complete"),
        (1, Event::Done { stats }) => {
            assert_eq!(stats.generated.len(), 8);
            neighbor_done = true;
        }
        (1, Event::Error { error }) => panic!("neighbor failed: {error}"),
        _ => {}
    })
    .unwrap();
    assert!(expired && neighbor_done);
    assert_eq!(e.stats.cancelled.get(), 1);
}
