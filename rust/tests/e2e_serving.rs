//! End-to-end serving tests: TCP server + engine + scheduler + backend.

use diagonal_batching::config::{ExecMode, Manifest, ModelConfig};
use diagonal_batching::coordinator::InferenceEngine;
use diagonal_batching::json::Value;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::server::{Client, Server};
use diagonal_batching::tensor::Rng;

fn test_config() -> ModelConfig {
    ModelConfig {
        name: "e2e".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 48,
        seg: 8,
        mem: 2,
        k_assoc: 4,
        dpfp_nu: 3,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 16,
        phi_dim: 24,
        seg_total: 10,
    }
}

fn native_engine(mode: ExecMode) -> InferenceEngine<NativeBackend> {
    let cfg = test_config();
    let params = Params::random(&cfg, 77);
    InferenceEngine::new(NativeBackend::new(cfg, params), mode)
}

fn toks(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(64) as u32).collect()
}

#[test]
fn serve_modes_and_stats_fields() {
    let server = Server::start(native_engine(ExecMode::Diagonal), "127.0.0.1:0", 8).unwrap();
    let mut c = Client::connect(&server.addr.to_string()).unwrap();

    let resp = c.infer(&toks(40, 1), None).unwrap();
    for field in [
        "id",
        "greedy_tail",
        "mode",
        "latency_ms",
        "segments",
        "launches",
        "mean_group",
        "cells",
        "padded_cells",
        "occupancy",
    ] {
        assert!(resp.get(field).is_some(), "missing {field}");
    }
    assert_eq!(resp.req("segments").unwrap().as_usize().unwrap(), 5);
    // S + L - 1 = 6 launches
    assert_eq!(resp.req("launches").unwrap().as_usize().unwrap(), 6);
    // A lone request in the wavefront pays the full ramp padding:
    // L * (S + L - 1) - S * L = L * (L - 1) = 2 cells at L = 2.
    assert_eq!(resp.req("cells").unwrap().as_usize().unwrap(), 10);
    assert_eq!(resp.req("padded_cells").unwrap().as_usize().unwrap(), 2);

    let seq = c.infer(&toks(40, 1), Some(ExecMode::Sequential)).unwrap();
    assert_eq!(seq.req("launches").unwrap().as_usize().unwrap(), 10);
    // both schedules greedy-decode identically on the native backend
    assert_eq!(
        resp.req("greedy_tail").unwrap().as_u32_vec().unwrap(),
        seq.req("greedy_tail").unwrap().as_u32_vec().unwrap()
    );

    // Aggregate stats over the wire (the sequential run's counters are
    // recorded before its reply, so these are race-free to read now).
    let stats = c
        .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
        .unwrap();
    assert!(stats.req("mean_group").unwrap().as_f64().unwrap() > 0.0);
    assert!(stats.get("padded_cells").is_some());
    assert!(stats.get("occupancy").is_some());
    assert_eq!(stats.req("packed_requests").unwrap().as_usize().unwrap(), 1);
    server.stop();
}

#[test]
fn serve_rejects_garbage_gracefully() {
    let server = Server::start(native_engine(ExecMode::Diagonal), "127.0.0.1:0", 8).unwrap();
    let mut c = Client::connect(&server.addr.to_string()).unwrap();
    // unparseable line
    let resp = c.roundtrip(&Value::Str("not an object".into())).unwrap();
    assert!(resp.get("error").is_some());
    // empty tokens
    let resp = c
        .roundtrip(&Value::obj(vec![("tokens", Value::Arr(vec![]))]))
        .unwrap();
    assert!(resp.get("error").is_some());
    // still alive
    assert!(c.ping().unwrap());
    server.stop();
}

#[test]
fn serve_many_requests_fifo_consistency() {
    let server = Server::start(native_engine(ExecMode::Auto), "127.0.0.1:0", 32).unwrap();
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let mut ok = 0;
            for i in 0..5 {
                let resp = c.infer(&toks(16 + 8 * (t as usize % 3), t * 100 + i), None).unwrap();
                assert!(resp.get("error").is_none());
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 30);
    server.stop();
}

#[test]
fn serve_hlo_backend_if_artifacts_present() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
    if !std::path::Path::new(path).exists() {
        return;
    }
    let m = Manifest::load(path).unwrap();
    let backend = HloBackend::load(&m, "micro").unwrap();
    let engine = InferenceEngine::new(backend, ExecMode::Diagonal);
    let server = Server::start(engine, "127.0.0.1:0", 4).unwrap();
    let mut c = Client::connect(&server.addr.to_string()).unwrap();
    let resp = c.infer(&toks(64, 3), None).unwrap();
    assert_eq!(resp.req("mode").unwrap().as_str().unwrap(), "diagonal");
    assert_eq!(resp.req("segments").unwrap().as_usize().unwrap(), 8);
    server.stop();
}

#[test]
fn client_disconnect_mid_stream_evicts_and_keeps_serving() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    let server = Server::start(native_engine(ExecMode::Diagonal), "127.0.0.1:0", 16).unwrap();
    let addr = server.addr.to_string();

    // Raw connection: start a huge generation, read a couple of event
    // frames, then DROP the socket mid-stream.
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let tokens: Vec<String> = (0..16).map(|i| (i % 60).to_string()).collect();
        writeln!(
            w,
            "{{\"id\": 77, \"tokens\": [{}], \"max_new_tokens\": 500000}}",
            tokens.join(", ")
        )
        .unwrap();
        for _ in 0..3 {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(line.contains("\"event\""), "expected an event frame, got {line}");
        }
        // Socket dropped here, mid-stream.
    }

    // The server notices on a failed frame write, cancels the request,
    // and evicts its lane. Poll stats until the eviction lands (bounded
    // by a watchdog).
    let mut c = Client::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let stats = c
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        if stats.req("cancelled").unwrap().as_usize().unwrap() >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect was never detected: {}",
            stats.to_json()
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // Other requests on the SAME engine stay bit-exact vs a fresh solo
    // engine with identical weights (seed 77 in native_engine).
    let probe = toks(40, 5);
    let served = c.infer(&probe, None).unwrap();
    let mut solo = native_engine(ExecMode::Diagonal);
    let want = solo
        .process(&diagonal_batching::coordinator::GenerateRequest::new(1, probe.clone()))
        .unwrap();
    assert_eq!(
        served.req("greedy_tail").unwrap().as_u32_vec().unwrap(),
        want.greedy_tail.iter().map(|&t| t as u32).collect::<Vec<u32>>(),
        "survivor diverged after an eviction"
    );
    server.stop();
}

#[test]
fn generation_burst_over_tcp_is_exact() {
    // Four concurrent TCP clients generating simultaneously: every
    // continuation must equal the same request's solo in-process run.
    let server = Server::start(native_engine(ExecMode::Diagonal), "127.0.0.1:0", 16).unwrap();
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt = toks(24, 100 + t);
            let done = c.generate(&prompt, 20, |_| {}).unwrap();
            (prompt, done.req("generated").unwrap().as_u32_vec().unwrap())
        }));
    }
    let mut solo = native_engine(ExecMode::Diagonal);
    for h in handles {
        let (prompt, generated) = h.join().unwrap();
        let want = solo
            .process(
                &diagonal_batching::coordinator::GenerateRequest::new(9, prompt).generate(20),
            )
            .unwrap();
        assert_eq!(generated, want.generated, "packed decode != solo decode");
    }
    server.stop();
}

#[test]
fn shutdown_via_protocol() {
    let server = Server::start(native_engine(ExecMode::Diagonal), "127.0.0.1:0", 4).unwrap();
    let addr = server.addr.to_string();
    let mut c = Client::connect(&addr).unwrap();
    c.shutdown().unwrap();
    // subsequent requests on a NEW connection should fail to be served
    // (queue closed); allow either connect failure or error response.
    if let Ok(mut c2) = Client::connect(&addr) {
        match c2.infer(&toks(8, 4), None) {
            Err(_) => {}
            Ok(resp) => assert!(resp.get("error").is_some()),
        }
    }
    server.stop();
}
