//! BABILong-task integration: the Table 3 property that matters for the
//! paper — diagonal batching gives the SAME answers as the sequential
//! ARMT implementation — plus generator/engine plumbing.

use diagonal_batching::babilong::{accuracy, Generator, Task};
use diagonal_batching::config::{BabilongSpec, ExecMode, Manifest, ModelConfig};
use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::scheduler::StepBackend;

fn spec() -> BabilongSpec {
    BabilongSpec {
        pad: 0,
        bos: 1,
        query: 2,
        sep: 3,
        agent_base: 10,
        n_agents: 8,
        place_base: 24,
        n_places: 16,
        object_base: 44,
        n_objects: 8,
        filler_base: 56,
        n_filler: 40,
    }
}

fn toy_like_config() -> ModelConfig {
    ModelConfig {
        name: "toy-like".into(),
        vocab: 96,
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 128,
        seg: 32,
        mem: 4,
        k_assoc: 16,
        dpfp_nu: 3,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![128],
        head_dim: 16,
        phi_dim: 96,
        seg_total: 36,
    }
}

fn answers<B: StepBackend>(
    engine: &mut InferenceEngine<B>,
    episodes: &[diagonal_batching::babilong::Episode],
    mode: ExecMode,
) -> Vec<u32> {
    let seg = engine.config().seg;
    episodes
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let mut req = GenerateRequest::new(i as u64, e.tokens.clone());
            req.want_logits = true;
            req.mode = Some(mode);
            let resp = engine.process(&req).unwrap();
            let pos = e.query_pos % seg;
            resp.logits.unwrap().last().unwrap().argmax_rows()[pos] as u32
        })
        .collect()
}

#[test]
fn diagonal_and_sequential_answers_identical_native() {
    // Table 3's "same scores" claim at the strongest level: identical
    // per-episode predictions (native backend is bit-exact).
    let cfg = toy_like_config();
    let params = Params::random(&cfg, 123);
    let mut engine =
        InferenceEngine::new(NativeBackend::new(cfg, params), ExecMode::Diagonal);
    let mut gen = Generator::new(spec(), 1);
    for task in [Task::QA1, Task::QA2] {
        for len in [64usize, 128, 256] {
            let eps = gen.batch(task, len, 6);
            let d = answers(&mut engine, &eps, ExecMode::Diagonal);
            let s = answers(&mut engine, &eps, ExecMode::Sequential);
            assert_eq!(d, s, "{task} len={len}");
        }
    }
}

#[test]
fn diagonal_and_sequential_answers_match_hlo() {
    // Same property through the real PJRT artifacts (toy bundle): logits
    // drift is allowed (Table 2) but decisions must agree.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
    if !std::path::Path::new(path).exists() {
        return;
    }
    let m = Manifest::load(path).unwrap();
    let backend = HloBackend::load(&m, "toy").unwrap();
    let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal);
    let mut gen = Generator::new(m.babilong.clone(), 2);
    let eps = gen.batch(Task::QA1, 128, 8);
    let d = answers(&mut engine, &eps, ExecMode::Diagonal);
    let s = answers(&mut engine, &eps, ExecMode::Sequential);
    let agree = d.iter().zip(&s).filter(|(a, b)| a == b).count();
    assert!(agree >= 7, "diag/seq answer agreement {agree}/8");
}

#[test]
fn trained_toy_beats_chance_if_available() {
    // Only meaningful after `make toy`; guards on the trained flag.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
    if !std::path::Path::new(path).exists() {
        return;
    }
    let m = Manifest::load(path).unwrap();
    let entry = m.model("toy").unwrap();
    if !entry.trained {
        eprintln!("toy model untrained; skipping accuracy check");
        return;
    }
    let backend = HloBackend::load(&m, "toy").unwrap();
    let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal);
    let mut gen = Generator::new(m.babilong.clone(), 3);
    let eps = gen.batch(Task::QA1, 64, 24);
    let preds = answers(&mut engine, &eps, ExecMode::Diagonal);
    let acc = accuracy(&eps, &preds);
    // chance is 1/16 = 6.25%; the trained model must clear it by a
    // comfortable margin
    assert!(acc > 0.2, "trained QA1 accuracy {acc}");
}

#[test]
fn generator_episode_lengths_exact() {
    let mut gen = Generator::new(spec(), 4);
    for len in [32usize, 64, 100, 256] {
        for task in [Task::QA1, Task::QA2] {
            let e = gen.episode(task, len);
            assert_eq!(e.tokens.len(), len);
            assert_eq!(e.query_pos, len - 1);
        }
    }
}
