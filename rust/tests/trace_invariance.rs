//! Observability invariance: tracing must never change what the engine
//! computes.
//!
//! The trace module's contract is two-sided. OFF (the default) the hot
//! path records nothing and every output byte matches a build that
//! predates the module. ON, spans only record timing metadata around
//! the same computation — logits, greedy tails and generated tokens
//! stay bit-identical to the sequential oracle at every worker thread
//! count, solo and packed. These tests also pin the export format
//! (valid Chrome-trace JSON, spans nested inside their request span,
//! one lane per tid) and the wire contract: a client-supplied `trace`
//! id is echoed on the done frame and stitches the server's spans,
//! while engine-assigned ids are never echoed.
//!
//! The collector is process-global, so every test here serializes on
//! one lock and leaves tracing DISABLED on exit.

use std::sync::Mutex;

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{
    Event, GenerateRequest, InferenceEngine, RequestQueue, Response,
};
use diagonal_batching::json::Value;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::server::{Client, Server};
use diagonal_batching::tensor::Rng;
use diagonal_batching::trace;

/// Serializes the tests in this binary: the trace ring and the
/// enabled flag are process-global.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn test_config() -> ModelConfig {
    ModelConfig {
        name: "trace-inv".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 48,
        seg: 8,
        mem: 2,
        k_assoc: 4,
        dpfp_nu: 3,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 16,
        phi_dim: 24,
        seg_total: 10,
    }
}

fn engine(mode: ExecMode, threads: usize) -> InferenceEngine<NativeBackend> {
    let cfg = test_config();
    let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 77)).with_threads(threads);
    InferenceEngine::new(backend, mode)
}

fn toks(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(64) as u32).collect()
}

fn logit_bits(r: &Response) -> Vec<Vec<u32>> {
    r.logits
        .as_ref()
        .expect("want_logits was set")
        .iter()
        .map(|t| t.data().iter().map(|x| x.to_bits()).collect())
        .collect()
}

/// Tracing on vs off vs the sequential oracle: bit-identical logits,
/// greedy tails and generated tokens at worker thread counts 1 and 3.
#[test]
fn tracing_toggle_is_bit_identical_to_sequential_oracle() {
    let _g = lock();
    trace::disable();

    let mut req = GenerateRequest::new(1, toks(3 * 8 + 5, 11)).generate(6);
    req.want_logits = true;
    let want = engine(ExecMode::Sequential, 1).process(&req).unwrap();

    for threads in [1usize, 3] {
        trace::disable();
        let off = engine(ExecMode::Diagonal, threads).process(&req).unwrap();

        trace::enable();
        trace::clear();
        let on = engine(ExecMode::Diagonal, threads).process(&req).unwrap();
        let spans = trace::len();
        trace::disable();

        let ctx = format!("threads {threads}");
        assert_eq!(logit_bits(&off), logit_bits(&want), "off-path drifted: {ctx}");
        assert_eq!(logit_bits(&on), logit_bits(&off), "tracing changed logits: {ctx}");
        assert_eq!(on.generated, off.generated, "tracing changed tokens: {ctx}");
        assert_eq!(on.greedy_tail, off.greedy_tail, "tracing changed greedy tail: {ctx}");
        assert_eq!(on.generated, want.generated, "{ctx}");
        assert!(spans > 0, "tracing on recorded nothing: {ctx}");
    }
}

/// A packed 4-request burst through the serving wavefront, traced:
/// the export is valid Chrome JSON, every request's engine-assigned
/// trace id carries prefill + decode spans nested inside its request
/// span, lanes map to distinct tids, and the outputs still match solo
/// untraced runs bit for bit.
#[test]
fn packed_burst_traces_every_request_and_stays_exact() {
    let _g = lock();
    trace::enable();
    trace::clear();

    let n_requests = 4usize;
    let requests: Vec<GenerateRequest> = (0..n_requests)
        .map(|i| GenerateRequest::new(i as u64, toks(2 * 8 + i, 40 + i as u64)).generate(5))
        .collect();
    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(n_requests);
    for req in &requests {
        queue.push((req.clone(), req.id)).unwrap();
    }
    queue.close();

    let cfg = test_config();
    let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 77));
    let mut eng = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(2);
    let mut done: Vec<(u64, Response)> = Vec::new();
    eng.serve_queue(&queue, |t, ev| match ev {
        Event::Done { stats } => done.push((*t, *stats)),
        Event::Error { error } => panic!("request {t} failed: {error}"),
        _ => {}
    })
    .unwrap();
    let json = trace::export_chrome();
    trace::disable();
    assert_eq!(done.len(), n_requests);

    // Traced packed outputs == solo untraced outputs.
    done.sort_by_key(|(id, _)| *id);
    for (id, got) in &done {
        let want = engine(ExecMode::Diagonal, 1).process(&requests[*id as usize]).unwrap();
        assert_eq!(got.generated, want.generated, "req {id}: tracing/packing drifted");
        assert_eq!(got.greedy_tail, want.greedy_tail, "req {id}");
    }

    // The export parses and every event satisfies the Chrome schema.
    let evs = Value::parse(&json).unwrap();
    let evs = evs.as_arr().unwrap();
    assert!(!evs.is_empty());
    for ev in evs {
        assert_eq!(ev.req("ph").unwrap().as_str().unwrap(), "X");
        for key in ["name", "ts", "dur", "pid", "tid", "args"] {
            assert!(ev.get(key).is_some(), "event missing {key}: {}", ev.to_json());
        }
    }
    let named = |name: &str| -> Vec<&Value> {
        evs.iter()
            .filter(|e| e.req("name").unwrap().as_str().unwrap() == name)
            .collect()
    };
    let arg = |e: &Value, k: &str| e.req("args").unwrap().req(k).unwrap().as_u64().unwrap();

    // One completion request span per request, distinct trace ids,
    // spanning at least two distinct lane tids.
    let req_spans: Vec<&Value> = named("request")
        .into_iter()
        .filter(|e| e.req("args").unwrap().get("cancelled").is_none())
        .collect();
    assert_eq!(req_spans.len(), n_requests, "one request span per request");
    let mut ids: Vec<u64> = req_spans.iter().map(|e| arg(e, "trace")).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n_requests, "trace ids must be distinct and nonzero");
    assert!(ids.iter().all(|&t| t != 0 && t < (1 << 48)));
    let mut lanes: Vec<u64> =
        req_spans.iter().map(|e| e.req("tid").unwrap().as_u64().unwrap()).collect();
    lanes.sort_unstable();
    lanes.dedup();
    assert!(lanes.len() >= 2, "4 requests over 2 lanes must use both: {lanes:?}");

    // Every trace id has admission, >= 1 prefill and >= 1 decode span,
    // all nested inside its request span's [ts, ts + dur].
    for rs in &req_spans {
        let tid = arg(rs, "trace");
        let lo = rs.req("ts").unwrap().as_u64().unwrap();
        let hi = lo + rs.req("dur").unwrap().as_u64().unwrap();
        for (name, at_least) in
            [("admit", 1usize), ("prefill_segment", 1), ("decode_token", 1)]
        {
            let inner: Vec<&Value> =
                named(name).into_iter().filter(|e| arg(e, "trace") == tid).collect();
            assert!(
                inner.len() >= at_least,
                "trace {tid}: want >= {at_least} {name} spans, got {}",
                inner.len()
            );
            for e in inner {
                let ts = e.req("ts").unwrap().as_u64().unwrap();
                let end = ts + e.req("dur").unwrap().as_u64().unwrap();
                assert!(
                    ts >= lo && end <= hi,
                    "trace {tid}: {name} [{ts}, {end}] outside request [{lo}, {hi}]"
                );
            }
        }
    }

    // The wavefront timeline rows landed on their reserved track with
    // the per-iteration shape attrs.
    let steps = named("wavefront_step");
    assert!(!steps.is_empty(), "no wavefront_step rows");
    for s in &steps {
        assert_eq!(s.req("tid").unwrap().as_u64().unwrap(), trace::TID_WAVEFRONT);
        for key in ["group", "padded", "launches", "kernel_ms", "in_flight"] {
            assert!(s.req("args").unwrap().get(key).is_some(), "step row missing {key}");
        }
    }
}

/// Wire contract over TCP: a client-supplied `trace` id is echoed on
/// the done frame and tags the server's spans; without one, the done
/// frame carries NO trace key even while tracing is on (engine-assigned
/// ids must never change output bytes). Latency histogram quantiles
/// ride the stats block either way.
#[test]
fn wire_trace_id_echoes_end_to_end() {
    let _g = lock();
    trace::enable();
    trace::clear();

    let server = Server::start(engine(ExecMode::Diagonal, 1), "127.0.0.1:0", 8).unwrap();
    let mut c = Client::connect(&server.addr.to_string()).unwrap();

    // With an explicit trace id: echoed verbatim, spans tagged with it.
    let done = c
        .request_stream(
            &Value::obj(vec![
                ("id", Value::Num(5.0)),
                ("tokens", Value::arr_u32(&toks(20, 9))),
                ("max_new_tokens", Value::Num(4.0)),
                ("trace", Value::Num(777.0)),
            ]),
            |_| {},
        )
        .unwrap();
    assert_eq!(done.req("trace").unwrap().as_u64().unwrap(), 777);
    let json = trace::export_chrome();
    let evs = Value::parse(&json).unwrap();
    let tagged = evs
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| {
            e.req("args")
                .ok()
                .and_then(|a| a.get("trace"))
                .and_then(|t| t.as_u64().ok())
                == Some(777)
        })
        .count();
    assert!(tagged >= 2, "want request + segment spans tagged 777, got {tagged}");

    // Without one: no trace key on the done frame, tracing on or off.
    let done = c
        .request_stream(
            &Value::obj(vec![
                ("id", Value::Num(6.0)),
                ("tokens", Value::arr_u32(&toks(20, 9))),
                ("max_new_tokens", Value::Num(4.0)),
            ]),
            |_| {},
        )
        .unwrap();
    assert!(
        done.get("trace").is_none(),
        "engine-assigned ids must not leak onto the wire: {}",
        done.to_json()
    );

    // Latency histograms surface as quantiles in the stats block.
    let stats = c
        .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
        .unwrap();
    for key in [
        "ttft_ms_p50",
        "ttft_ms_p99",
        "inter_token_ms_p50",
        "queue_wait_ms_p50",
        "queue_wait_ms_p99",
    ] {
        assert!(stats.get(key).is_some(), "stats missing {key}: {}", stats.to_json());
    }
    assert!(stats.req("ttft_ms_p50").unwrap().as_f64().unwrap() >= 0.0);

    // The protocol's trace dump returns the same ring as a command.
    let dump = c
        .roundtrip(&Value::obj(vec![("cmd", Value::Str("trace".into()))]))
        .unwrap();
    assert!(dump.req("ok").unwrap().as_bool().unwrap());
    assert!(dump.req("enabled").unwrap().as_bool().unwrap());
    assert!(!dump.req("events").unwrap().as_arr().unwrap().is_empty());

    trace::disable();
    server.stop();
}

/// Tracing off at the wire level: the done frame still echoes a
/// client-supplied trace id (the echo is protocol-level, not a trace
/// feature), and nothing lands in the ring.
#[test]
fn trace_echo_works_with_collector_off() {
    let _g = lock();
    trace::disable();
    trace::clear();

    let server = Server::start(engine(ExecMode::Diagonal, 1), "127.0.0.1:0", 8).unwrap();
    let mut c = Client::connect(&server.addr.to_string()).unwrap();
    let done = c
        .request_stream(
            &Value::obj(vec![
                ("id", Value::Num(7.0)),
                ("tokens", Value::arr_u32(&toks(16, 2))),
                ("trace", Value::Num(4242.0)),
            ]),
            |_| {},
        )
        .unwrap();
    assert_eq!(done.req("trace").unwrap().as_u64().unwrap(), 4242);
    assert_eq!(trace::len(), 0, "collector off must record nothing");
    server.stop();
}
