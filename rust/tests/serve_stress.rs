//! Concurrency stress test for `serve_queue` on the pooled backend:
//! multiple producer threads hammer the bounded queue with mixed-length
//! requests while another thread polls the shared `EngineStats`
//! snapshot, and the engine drains everything through one packed
//! wavefront executing on a worker-thread cell pool.
//!
//! Asserted invariants:
//! * **liveness** — the whole run finishes under a watchdog; a deadlock
//!   anywhere (queue, pool channels, stats locks) aborts the test with
//!   a distinct exit code instead of hanging CI;
//! * **exactly-once completion** — every submitted request completes
//!   exactly once, none lost, none duplicated, none failed;
//! * **counter consistency** — concurrent stats snapshots never observe
//!   `active > slot_steps` or `busy > capacity`, and the final counters
//!   sum up: requests == packed == submitted, token totals match, pool
//!   cells never exceed active cells.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{Event, GenerateRequest, InferenceEngine, RequestQueue};
use diagonal_batching::json::Value;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::server::{Client, Server, ServerOptions};
use diagonal_batching::shard::{CoordinatorOptions, FaultPlan, ShardCoordinator};

const PRODUCERS: usize = 4;
const PER_PRODUCER: usize = 12;
const QUEUE_DEPTH: usize = 16; // << total, so producers hit backpressure

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "stress".into(),
        vocab: 32,
        d_model: 16,
        n_layers: 4,
        n_heads: 2,
        d_ff: 24,
        seg: 4,
        mem: 2,
        k_assoc: 4,
        dpfp_nu: 2,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 8,
        phi_dim: 16,
        seg_total: 6,
    }
}

/// Segment count for request `id` (mixed lengths, 1..=4).
fn segments_for(id: u64) -> usize {
    1 + (id as usize % 4)
}

fn tokens_for(id: u64, seg: usize) -> Vec<u32> {
    let segs = segments_for(id);
    let ragged = id as usize % 3; // many requests end mid-segment
    let n = (segs * seg).saturating_sub(ragged).max(1);
    (0..n as u32).map(|t| (t * 13 + id as u32) % 32).collect()
}

#[test]
fn serve_queue_pooled_concurrent_stress() {
    let c = cfg();
    let total = (PRODUCERS * PER_PRODUCER) as u64;
    let mut engine = InferenceEngine::new(
        NativeBackend::new(c.clone(), Params::random(&c, 17)).with_threads(3),
        ExecMode::Diagonal,
    )
    .with_lanes(2);
    let stats = engine.stats_handle();
    let queue: Arc<RequestQueue<(GenerateRequest, u64)>> = Arc::new(RequestQueue::new(QUEUE_DEPTH));

    // Watchdog: a deadlock must fail the test run, not hang it. The
    // budget is generous (debug builds, loaded CI machines); a healthy
    // run takes well under a second.
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for _ in 0..1200 {
                std::thread::sleep(Duration::from_millis(100));
                if done.load(Ordering::SeqCst) {
                    return;
                }
            }
            eprintln!("serve_stress: watchdog fired — serve_queue deadlocked");
            std::process::exit(101);
        });
    }

    // Producers: disjoint id ranges, retry on backpressure.
    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let queue = Arc::clone(&queue);
            let seg = c.seg;
            std::thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    let id = (p * PER_PRODUCER + i) as u64;
                    let mut job = (GenerateRequest::new(id, tokens_for(id, seg)), id);
                    // Bounded blocking push: sleeps on the queue's
                    // condvar until the drain loop frees a slot (no
                    // busy-spin); a failed attempt hands the job back.
                    loop {
                        match queue.push_timeout(job, Duration::from_millis(50)) {
                            Ok(()) => break,
                            Err((j, _)) => job = j,
                        }
                    }
                }
            })
        })
        .collect();

    // Closer: once every producer has drained its range, close the
    // queue so serve_queue exits after the in-flight tail completes.
    {
        let queue = Arc::clone(&queue);
        std::thread::spawn(move || {
            for h in producers {
                h.join().expect("producer panicked");
            }
            queue.close();
        });
    }

    // Stats poller: concurrent snapshots must always be internally
    // consistent, and the JSON export must never panic mid-serve.
    let poller = {
        let stats = Arc::clone(&stats);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut snapshots = 0u64;
            while !done.load(Ordering::SeqCst) {
                let (active, slots) = stats.occupancy.parts();
                assert!(active <= slots, "occupancy snapshot tore: {active} > {slots}");
                let (busy, cap) = stats.worker_busy.parts();
                assert!(busy <= cap, "worker_busy snapshot tore: {busy} > {cap}");
                let js = stats.to_json().to_json();
                assert!(js.contains("\"occupancy\""), "stats JSON lost a field: {js}");
                snapshots += 1;
                std::thread::sleep(Duration::from_micros(500));
            }
            snapshots
        })
    };

    // Drain on this thread; terminal events land in the closure.
    let mut completed: Vec<u64> = Vec::new();
    engine
        .serve_queue(&queue, |ticket, ev| match ev {
            Event::Done { stats: resp } => {
                assert_eq!(resp.id, *ticket, "response routed to the wrong ticket");
                assert!(!resp.greedy_tail.is_empty(), "request {ticket} produced no output");
                completed.push(*ticket);
            }
            Event::Error { error } => panic!("request {ticket} failed under load: {error}"),
            _ => {}
        })
        .unwrap();
    done.store(true, Ordering::SeqCst);
    let snapshots = poller.join().expect("stats poller panicked");
    assert!(snapshots > 0, "poller never ran while serving");

    // Exactly-once: all ids, no losses, no duplicates.
    completed.sort_unstable();
    assert_eq!(completed.len() as u64, total, "lost or duplicated completions");
    for (i, id) in completed.iter().enumerate() {
        assert_eq!(*id, i as u64, "completion set has a hole or a duplicate");
    }

    // Final counters sum consistently.
    assert_eq!(stats.requests.get(), total);
    assert_eq!(stats.packed_requests.get(), total);
    assert_eq!(stats.rejected.get(), 0);
    // `tokens` counts prompt tokens as submitted (unpadded).
    let expect_tokens: u64 =
        (0..total).map(|id| tokens_for(id, c.seg).len() as u64).sum();
    assert_eq!(stats.tokens.get(), expect_tokens, "token accounting drifted");

    let (active, slots) = stats.occupancy.parts();
    assert!(active > 0 && active <= slots);
    // Each request needs exactly S*L cells; the session computed all of
    // them and nothing else.
    let expect_cells: u64 =
        (0..total).map(|id| (segments_for(id) * c.n_layers) as u64).sum();
    assert_eq!(active, expect_cells, "active-cell accounting drifted");

    // Pool accounting: 3 workers were live; pooled cells are a subset
    // of active cells (single-cell wavefront tips run inline).
    assert_eq!(stats.workers.get(), 3);
    assert!(stats.pool_cells.get() > 0, "pool never executed a cell");
    assert!(stats.pool_cells.get() <= active, "pool executed phantom cells");
    let (busy, cap) = stats.worker_busy.parts();
    assert!(busy <= cap);
}

// ---------------------------------------------------------------------------
// Sharded serving stress: a coordinator over in-process workers under
// mixed generate / cancel / save traffic, with a scripted worker death
// mid-burst and a replacement attached live via `shard_attach`.
//
// Asserted invariants:
// * liveness under its own watchdog — a wedged coordinator aborts with
//   a distinct exit code;
// * exactly-once completion: every request gets exactly one terminal
//   frame (checked by pinging the same connection right after it), and
//   every non-cancelled request completes despite the worker death;
// * conserved accounting: the coordinator's `generated_tokens` counter
//   equals the sum of tokens actually delivered in `done` frames, and
//   the worker gauge tracks dead + attached workers.

const SHARD_PRODUCERS: usize = 3;
const SHARD_PER_PRODUCER: usize = 6;
const SHARD_SEED: u64 = 0x99;

fn shard_worker_server(fault: Option<FaultPlan>) -> Server {
    let c = ModelConfig::synthetic();
    let engine = InferenceEngine::new(
        NativeBackend::new(c.clone(), Params::random(&c, SHARD_SEED)),
        ExecMode::Diagonal,
    );
    Server::start_with(engine, "127.0.0.1:0", 16, ServerOptions { fault, ..Default::default() })
        .unwrap()
}

#[test]
fn shard_coordinator_mixed_traffic_with_scripted_death_and_attach() {
    let cfg = ModelConfig::synthetic();
    let w1 = shard_worker_server(None);
    // Dies after 60 protocol frames — mid-burst, with several requests
    // in flight on it.
    let w2 = shard_worker_server(Some(FaultPlan::DieAfterFrames(60)));
    let coord = ShardCoordinator::start(
        cfg.clone(),
        &[w1.addr.to_string(), w2.addr.to_string()],
        "127.0.0.1:0",
        CoordinatorOptions::default(),
    )
    .unwrap();
    let addr = coord.addr.to_string();
    let stats = coord.stats();

    // Watchdog: fault handling must be bounded.
    let done_flag = Arc::new(AtomicBool::new(false));
    {
        let done_flag = Arc::clone(&done_flag);
        std::thread::spawn(move || {
            for _ in 0..1800 {
                std::thread::sleep(Duration::from_millis(100));
                if done_flag.load(Ordering::SeqCst) {
                    return;
                }
            }
            eprintln!("serve_stress: watchdog fired — shard coordinator wedged");
            std::process::exit(103);
        });
    }

    // Control thread: once the scripted death has caused a failover,
    // attach a fresh replacement worker (the "restart").
    let replacement: Arc<Mutex<Option<Server>>> = Arc::new(Mutex::new(None));
    let control = {
        let addr = addr.clone();
        let stats = Arc::clone(&stats);
        let replacement = Arc::clone(&replacement);
        let done_flag = Arc::clone(&done_flag);
        std::thread::spawn(move || {
            while stats.shard_failovers.get() == 0 {
                if done_flag.load(Ordering::SeqCst) {
                    return false; // burst finished before the fault fired
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            let w3 = shard_worker_server(None);
            let mut c = Client::connect(&addr).unwrap();
            let reply = c
                .roundtrip(&Value::obj(vec![
                    ("cmd", Value::Str("shard_attach".into())),
                    ("addr", Value::Str(w3.addr.to_string())),
                ]))
                .unwrap();
            assert!(reply.req("ok").unwrap().as_bool().unwrap());
            *replacement.lock().unwrap() = Some(w3);
            true
        })
    };

    // Producers: mixed prompt lengths and decode budgets, every third
    // request asks for a `save` resume token.
    let completions: Arc<Mutex<Vec<(u64, usize, Option<u64>)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let producers: Vec<_> = (0..SHARD_PRODUCERS)
        .map(|p| {
            let addr = addr.clone();
            let completions = Arc::clone(&completions);
            let seg = cfg.seg;
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..SHARD_PER_PRODUCER {
                    let id = (p * 100 + i) as u64;
                    let n_segs = 1 + (p + i) % 3;
                    let prompt: Vec<u32> =
                        (0..n_segs * seg).map(|t| ((t as u32) * 11 + id as u32) % 64).collect();
                    let max_new = [0usize, 4, 8][(p + 2 * i) % 3];
                    let mut fields = vec![
                        ("id", Value::Num(id as f64)),
                        ("tokens", Value::arr_u32(&prompt)),
                        ("max_new_tokens", Value::Num(max_new as f64)),
                    ];
                    if i % 3 == 0 {
                        fields.push(("save", Value::Bool(true)));
                    }
                    let done = client
                        .request_stream(&Value::obj(fields), |_| {})
                        .unwrap_or_else(|e| panic!("request {id} failed: {e}"));
                    // Exactly-once: a duplicated terminal frame would be
                    // consumed as this ping's reply and fail it.
                    assert!(client.ping().unwrap(), "stray frame after done for {id}");
                    let generated =
                        done.req("generated").unwrap().as_u32_vec().unwrap().len();
                    assert_eq!(generated, max_new, "request {id} token budget");
                    let token = done.get("resume_token").map(|v| v.as_u64().unwrap());
                    assert_eq!(token.is_some(), i % 3 == 0, "request {id} save handling");
                    completions.lock().unwrap().push((id, generated, token));
                }
            })
        })
        .collect();

    // Cancel traffic: a long-running request cancelled from a second
    // connection. Depending on timing it terminates with a cancel
    // error or races to a clean `done`; both are exactly-once.
    let canceller = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut victim = Client::connect(&addr).unwrap();
            let frame = Value::obj(vec![
                ("id", Value::Num(999.0)),
                ("tokens", Value::arr_u32(&(0..8).collect::<Vec<u32>>())),
                ("max_new_tokens", Value::Num(4096.0)),
            ]);
            let killer = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(100));
                let mut c = Client::connect(&addr).unwrap();
                c.cancel(999).unwrap()
            });
            let outcome = victim.request_stream(&frame, |_| {});
            let _found = killer.join().unwrap();
            match outcome {
                Ok(done) => done.req("generated").unwrap().as_u32_vec().unwrap().len(),
                Err(_) => 0, // cancelled before completion: no done frame
            }
        })
    };

    for h in producers {
        h.join().expect("producer panicked");
    }
    let cancel_generated = canceller.join().expect("canceller panicked");
    done_flag.store(true, Ordering::SeqCst);
    let attached = control.join().expect("control thread panicked");

    // Exactly-once across the burst: all ids, no losses, no duplicates.
    let mut got = completions.lock().unwrap().clone();
    got.sort_unstable_by_key(|(id, _, _)| *id);
    let ids: Vec<u64> = got.iter().map(|(id, _, _)| *id).collect();
    let want: Vec<u64> = (0..SHARD_PRODUCERS)
        .flat_map(|p| (0..SHARD_PER_PRODUCER).map(move |i| (p * 100 + i) as u64))
        .collect();
    assert_eq!(ids, want, "lost or duplicated completions");

    // Resume tokens are coordinator-scoped and unique.
    let mut tokens: Vec<u64> = got.iter().filter_map(|(_, _, t)| *t).collect();
    let n_saved = tokens.len();
    tokens.sort_unstable();
    tokens.dedup();
    assert_eq!(tokens.len(), n_saved, "duplicate resume tokens handed out");

    // Conserved accounting: the coordinator counted exactly the tokens
    // it delivered in `done` frames — across failovers too.
    let delivered: u64 =
        got.iter().map(|(_, n, _)| *n as u64).sum::<u64>() + cancel_generated as u64;
    assert_eq!(stats.generated_tokens.get(), delivered, "token accounting drifted");
    assert!(
        stats.shard_routed.get() >= (SHARD_PRODUCERS * SHARD_PER_PRODUCER) as u64,
        "routing undercounted"
    );

    if attached {
        // The scripted death fired: the dead worker left the gauge and
        // the replacement joined it (1 survivor + 1 attached).
        assert!(stats.shard_failovers.get() >= 1);
        assert_eq!(stats.shard_workers.get(), 2, "worker gauge drifted");
        // The replacement actually serves: one more request through the
        // coordinator after the burst.
        let mut c = Client::connect(&addr).unwrap();
        let frame = Value::obj(vec![
            ("tokens", Value::arr_u32(&(0..16).collect::<Vec<u32>>())),
            ("max_new_tokens", Value::Num(4.0)),
        ]);
        let deadline = Instant::now() + Duration::from_secs(60);
        let done = c.request_stream(&frame, |_| {}).unwrap();
        assert_eq!(done.req("generated").unwrap().as_u32_vec().unwrap().len(), 4);
        assert!(Instant::now() < deadline);
    }

    coord.stop();
    w1.stop();
    w2.stop();
    if let Some(w3) = replacement.lock().unwrap().take() {
        w3.stop();
    }
}
