//! Concurrency stress test for `serve_queue` on the pooled backend:
//! multiple producer threads hammer the bounded queue with mixed-length
//! requests while another thread polls the shared `EngineStats`
//! snapshot, and the engine drains everything through one packed
//! wavefront executing on a worker-thread cell pool.
//!
//! Asserted invariants:
//! * **liveness** — the whole run finishes under a watchdog; a deadlock
//!   anywhere (queue, pool channels, stats locks) aborts the test with
//!   a distinct exit code instead of hanging CI;
//! * **exactly-once completion** — every submitted request completes
//!   exactly once, none lost, none duplicated, none failed;
//! * **counter consistency** — concurrent stats snapshots never observe
//!   `active > slot_steps` or `busy > capacity`, and the final counters
//!   sum up: requests == packed == submitted, token totals match, pool
//!   cells never exceed active cells.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{Event, GenerateRequest, InferenceEngine, RequestQueue};
use diagonal_batching::model::{NativeBackend, Params};

const PRODUCERS: usize = 4;
const PER_PRODUCER: usize = 12;
const QUEUE_DEPTH: usize = 16; // << total, so producers hit backpressure

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "stress".into(),
        vocab: 32,
        d_model: 16,
        n_layers: 4,
        n_heads: 2,
        d_ff: 24,
        seg: 4,
        mem: 2,
        k_assoc: 4,
        dpfp_nu: 2,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 8,
        phi_dim: 16,
        seg_total: 6,
    }
}

/// Segment count for request `id` (mixed lengths, 1..=4).
fn segments_for(id: u64) -> usize {
    1 + (id as usize % 4)
}

fn tokens_for(id: u64, seg: usize) -> Vec<u32> {
    let segs = segments_for(id);
    let ragged = id as usize % 3; // many requests end mid-segment
    let n = (segs * seg).saturating_sub(ragged).max(1);
    (0..n as u32).map(|t| (t * 13 + id as u32) % 32).collect()
}

#[test]
fn serve_queue_pooled_concurrent_stress() {
    let c = cfg();
    let total = (PRODUCERS * PER_PRODUCER) as u64;
    let mut engine = InferenceEngine::new(
        NativeBackend::new(c.clone(), Params::random(&c, 17)).with_threads(3),
        ExecMode::Diagonal,
    )
    .with_lanes(2);
    let stats = engine.stats_handle();
    let queue: Arc<RequestQueue<(GenerateRequest, u64)>> = Arc::new(RequestQueue::new(QUEUE_DEPTH));

    // Watchdog: a deadlock must fail the test run, not hang it. The
    // budget is generous (debug builds, loaded CI machines); a healthy
    // run takes well under a second.
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for _ in 0..1200 {
                std::thread::sleep(Duration::from_millis(100));
                if done.load(Ordering::SeqCst) {
                    return;
                }
            }
            eprintln!("serve_stress: watchdog fired — serve_queue deadlocked");
            std::process::exit(101);
        });
    }

    // Producers: disjoint id ranges, retry on backpressure.
    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let queue = Arc::clone(&queue);
            let seg = c.seg;
            std::thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    let id = (p * PER_PRODUCER + i) as u64;
                    let req = GenerateRequest::new(id, tokens_for(id, seg));
                    let mut job = (req, id);
                    loop {
                        match queue.push(job) {
                            Ok(()) => break,
                            Err(_) => {
                                // Queue full: victims of our own load
                                // test. Back off briefly and retry.
                                std::thread::sleep(Duration::from_micros(200));
                                job = (GenerateRequest::new(id, tokens_for(id, seg)), id);
                            }
                        }
                    }
                }
            })
        })
        .collect();

    // Closer: once every producer has drained its range, close the
    // queue so serve_queue exits after the in-flight tail completes.
    {
        let queue = Arc::clone(&queue);
        std::thread::spawn(move || {
            for h in producers {
                h.join().expect("producer panicked");
            }
            queue.close();
        });
    }

    // Stats poller: concurrent snapshots must always be internally
    // consistent, and the JSON export must never panic mid-serve.
    let poller = {
        let stats = Arc::clone(&stats);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut snapshots = 0u64;
            while !done.load(Ordering::SeqCst) {
                let (active, slots) = stats.occupancy.parts();
                assert!(active <= slots, "occupancy snapshot tore: {active} > {slots}");
                let (busy, cap) = stats.worker_busy.parts();
                assert!(busy <= cap, "worker_busy snapshot tore: {busy} > {cap}");
                let js = stats.to_json().to_json();
                assert!(js.contains("\"occupancy\""), "stats JSON lost a field: {js}");
                snapshots += 1;
                std::thread::sleep(Duration::from_micros(500));
            }
            snapshots
        })
    };

    // Drain on this thread; terminal events land in the closure.
    let mut completed: Vec<u64> = Vec::new();
    engine
        .serve_queue(&queue, |ticket, ev| match ev {
            Event::Done { stats: resp } => {
                assert_eq!(resp.id, *ticket, "response routed to the wrong ticket");
                assert!(!resp.greedy_tail.is_empty(), "request {ticket} produced no output");
                completed.push(*ticket);
            }
            Event::Error { error } => panic!("request {ticket} failed under load: {error}"),
            _ => {}
        })
        .unwrap();
    done.store(true, Ordering::SeqCst);
    let snapshots = poller.join().expect("stats poller panicked");
    assert!(snapshots > 0, "poller never ran while serving");

    // Exactly-once: all ids, no losses, no duplicates.
    completed.sort_unstable();
    assert_eq!(completed.len() as u64, total, "lost or duplicated completions");
    for (i, id) in completed.iter().enumerate() {
        assert_eq!(*id, i as u64, "completion set has a hole or a duplicate");
    }

    // Final counters sum consistently.
    assert_eq!(stats.requests.get(), total);
    assert_eq!(stats.packed_requests.get(), total);
    assert_eq!(stats.rejected.get(), 0);
    // `tokens` counts prompt tokens as submitted (unpadded).
    let expect_tokens: u64 =
        (0..total).map(|id| tokens_for(id, c.seg).len() as u64).sum();
    assert_eq!(stats.tokens.get(), expect_tokens, "token accounting drifted");

    let (active, slots) = stats.occupancy.parts();
    assert!(active > 0 && active <= slots);
    // Each request needs exactly S*L cells; the session computed all of
    // them and nothing else.
    let expect_cells: u64 =
        (0..total).map(|id| (segments_for(id) * c.n_layers) as u64).sum();
    assert_eq!(active, expect_cells, "active-cell accounting drifted");

    // Pool accounting: 3 workers were live; pooled cells are a subset
    // of active cells (single-cell wavefront tips run inline).
    assert_eq!(stats.workers.get(), 3);
    assert!(stats.pool_cells.get() > 0, "pool never executed a cell");
    assert!(stats.pool_cells.get() <= active, "pool executed phantom cells");
    let (busy, cap) = stats.worker_busy.parts();
    assert!(busy <= cap);
}
