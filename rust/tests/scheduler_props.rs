//! Property-based tests of the scheduler (hand-rolled generation — the
//! offline toolchain has no proptest; cases are driven by the crate's
//! deterministic PRNG, so failures reproduce exactly).
//!
//! Properties:
//!  * P1 (Lemma 3.1): for all grids, the diagonal schedule is valid,
//!    uses exactly S+L-1 groups, and places every cell at its earliest
//!    feasible group.
//!  * P2: the sequential schedule is always valid; its group count is
//!    S*L.
//!  * P3: corrupting any single cell's group assignment downward breaks
//!    validity (the earliest-placement bound is tight).
//!  * P4: for random model shapes, seeds and lengths, the diagonal
//!    executor's logits are BIT-IDENTICAL to the sequential executor's
//!    on the native backend.
//!  * P5: run stats match the Fig. 3 launch arithmetic.
//!  * P7: N concurrent requests packed through a `WavefrontSession`
//!    (random lane counts, ragged lengths, mid-flight admission) produce
//!    logits BIT-IDENTICAL to N independent sequential runs — the
//!    packing refactor's exactness contract.
//!  * P8: packing N >= 2 requests never lowers the session's mean group
//!    size below the best solo diagonal run of the same batch.
//!  * P10: for random workloads, packed-session results are invariant
//!    to the worker-pool thread count AND to worker scheduling jitter
//!    (randomized per-cell sleeps injected via the pool's test hook) —
//!    logits bit-identical, deterministic stats fields identical.
//!  * P13: weighted-fair admission is starvation-free (a late light
//!    tenant is served within two pops of a flooding heavy one; every
//!    prefix of the pop order tracks the weight shares within one
//!    job), overload sheds as a clean queue-full error, and for random
//!    tenant mixes every ADMITTED request's output is bit-identical to
//!    a solo sequential run at every thread count — fairness reorders
//!    admission, never arithmetic.
//!  * P14: segment selection (`overflow: "select"`) gates exactly the
//!    segments the pure token-level plan (`quality::plan_selection`)
//!    names — no more, no fewer — and the gated run's logits,
//!    skip count and saturation are bit-identical across worker thread
//!    counts; when the plan names nothing, the run is bit-identical to
//!    policy-off.

use diagonal_batching::config::ModelConfig;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::scheduler::dag::{
    check_earliest_placement, check_minimality, min_groups, validate_schedule,
};
use diagonal_batching::scheduler::{Executor, Schedule, ScheduleMode, WavefrontSession};
use diagonal_batching::tensor::Rng;

#[test]
fn p1_diagonal_is_optimal_everywhere() {
    let mut rng = Rng::new(0xD1A6);
    for _ in 0..200 {
        let s = 1 + rng.below(40);
        let l = 1 + rng.below(24);
        let d = Schedule::diagonal(s, l);
        validate_schedule(&d.groups, s, l).unwrap();
        check_minimality(&d.groups, s, l).unwrap();
        check_earliest_placement(&d.groups).unwrap();
        assert_eq!(d.group_count(), min_groups(s, l), "S={s} L={l}");
        assert_eq!(d.cell_count(), s * l);
        assert!(d.max_group() <= l.min(s).max(1));
    }
}

#[test]
fn p2_sequential_always_valid() {
    let mut rng = Rng::new(0x5E9);
    for _ in 0..100 {
        let s = 1 + rng.below(30);
        let l = 1 + rng.below(16);
        let sched = Schedule::sequential(s, l);
        sched.validate().unwrap();
        assert_eq!(sched.group_count(), s * l);
    }
}

#[test]
fn p3_earliest_placement_is_tight() {
    // Moving any non-origin cell one group earlier must break validity.
    let mut rng = Rng::new(0x71F);
    for _ in 0..50 {
        let s = 2 + rng.below(10);
        let l = 2 + rng.below(6);
        let d = Schedule::diagonal(s, l);
        // pick a random cell not in group 0
        let gi = 1 + rng.below(d.groups.len() - 1);
        let ci = rng.below(d.groups[gi].len());
        let mut groups = d.groups.clone();
        let cell = groups[gi].remove(ci);
        groups[gi - 1].push(cell);
        assert!(
            validate_schedule(&groups, s, l).is_err(),
            "moving {cell:?} from group {gi} to {} should violate a dependency",
            gi - 1
        );
    }
}

fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(3); // 1..=3
    let head_dim = [4usize, 8][rng.below(2)];
    let d_model = n_heads * head_dim;
    let k_assoc = [4usize, 8][rng.below(2)];
    let nu = 1 + rng.below(3);
    let seg = 4 + rng.below(8);
    let mem = 1 + rng.below(4);
    let n_layers = 1 + rng.below(4);
    ModelConfig {
        name: "prop".into(),
        vocab: 32 + rng.below(64),
        d_model,
        n_layers,
        n_heads,
        d_ff: d_model * 2,
        seg,
        mem,
        k_assoc,
        dpfp_nu: nu,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim,
        phi_dim: 2 * nu * k_assoc,
        seg_total: seg + mem,
    }
}

#[test]
fn p4_diagonal_bitexact_vs_sequential_over_random_models() {
    let mut rng = Rng::new(0xB17);
    for case in 0..25 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let n_segments = 1 + rng.below(7);
        let n_tokens = n_segments * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
        let tokens: Vec<u32> =
            (0..n_tokens).map(|_| rng.below(cfg.vocab) as u32).collect();

        let mut b1 = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let seq = Executor::new(&mut b1, ScheduleMode::Sequential).run(&tokens).unwrap();
        let mut b2 = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let diag = Executor::new(&mut b2, ScheduleMode::Diagonal).run(&tokens).unwrap();

        assert_eq!(seq.segments(), diag.segments(), "case {case}");
        for (s_i, (a, b)) in seq.logits.iter().zip(&diag.logits).enumerate() {
            assert_eq!(a, b, "case {case} segment {s_i} cfg {cfg:?}");
        }
    }
}

#[test]
fn p5_launch_counts_follow_fig3() {
    let mut rng = Rng::new(0xF16);
    for _ in 0..20 {
        let cfg = random_config(&mut rng);
        let seed = rng.next_u64();
        let s = 1 + rng.below(9);
        let tokens: Vec<u32> = (0..s * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
        let l = cfg.n_layers;

        let mut b = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let seq = Executor::new(&mut b, ScheduleMode::Sequential).run(&tokens).unwrap();
        // sequential: S*L cell-step launches (embed/head are not steps)
        assert_eq!(seq.stats.launches, (s * l) as u64);

        let mut b = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let diag = Executor::new(&mut b, ScheduleMode::Diagonal).run(&tokens).unwrap();
        assert_eq!(diag.stats.launches, (s + l - 1) as u64);
        assert_eq!(diag.stats.cells, (s * l) as u64);
        // padded cells = L*(S+L-1) - S*L = L(L-1) (both ramps) when S >= L
        if s >= l {
            assert_eq!(diag.stats.padded_cells, (l * (l - 1)) as u64);
        }
    }
}

#[test]
fn p7_packed_session_bitexact_vs_independent_sequential() {
    let mut rng = Rng::new(0x7AC);
    for case in 0..12 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let lanes = 1 + rng.below(3);
        let n_requests = 2 + rng.below(4);
        let requests: Vec<Vec<u32>> = (0..n_requests)
            .map(|_| {
                let s = 1 + rng.below(6);
                let n = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
                (0..n).map(|_| rng.below(cfg.vocab) as u32).collect()
            })
            .collect();

        // Packed: one backend, one session; admit half up front and the
        // rest mid-flight.
        let mut backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let mut session = WavefrontSession::new(cfg.clone(), lanes);
        let split = n_requests / 2;
        for (i, toks) in requests.iter().take(split).enumerate() {
            session.submit(i as u64, toks).unwrap();
        }
        for _ in 0..rng.below(4) {
            session.step(&mut backend).unwrap();
        }
        for (i, toks) in requests.iter().enumerate().skip(split) {
            session.submit(i as u64, toks).unwrap();
        }
        session.run_to_completion(&mut backend).unwrap();
        let mut outs = session.drain_completed();
        assert_eq!(outs.len(), n_requests, "case {case}");
        outs.sort_by_key(|o| o.id);

        // Reference: each request alone, sequential schedule, fresh
        // backend with the same weights.
        for (i, toks) in requests.iter().enumerate() {
            let mut b = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
            let want = Executor::new(&mut b, ScheduleMode::Sequential).run(toks).unwrap();
            assert_eq!(outs[i].logits.len(), want.segments(), "case {case} request {i}");
            for (s_i, (a, b)) in outs[i].logits.iter().zip(&want.logits).enumerate() {
                assert_eq!(
                    a, b,
                    "case {case} request {i} segment {s_i} lanes {lanes} cfg {cfg:?}"
                );
            }
        }
    }
}

#[test]
fn p8_packing_never_lowers_mean_group() {
    let mut rng = Rng::new(0xF111);
    for _ in 0..12 {
        let cfg = random_config(&mut rng);
        let seed = rng.next_u64();
        let lanes = 1 + rng.below(2);
        let n_requests = 2 + rng.below(3);
        let seg_counts: Vec<usize> = (0..n_requests).map(|_| 1 + rng.below(5)).collect();
        let l = cfg.n_layers;

        let mut backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let mut session = WavefrontSession::new(cfg.clone(), lanes);
        for (i, &s) in seg_counts.iter().enumerate() {
            let toks: Vec<u32> = (0..s * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
            session.submit(i as u64, &toks).unwrap();
        }
        session.run_to_completion(&mut backend).unwrap();
        let packed = session.stats();
        assert_eq!(packed.cells, (seg_counts.iter().sum::<usize>() * l) as u64);

        let solo_best = seg_counts
            .iter()
            .map(|&s| (s * l) as f64 / (s + l - 1) as f64)
            .fold(0.0, f64::max);
        assert!(
            packed.mean_group() >= solo_best - 1e-9,
            "packed {} vs solo best {solo_best} (lanes {lanes}, segs {seg_counts:?}, L {l})",
            packed.mean_group()
        );
    }
}

#[test]
fn p9_packed_plan_mirrors_live_session() {
    // `Schedule::packed` re-derives the session's lane-assignment /
    // injection behavior for the simulator. This property pins the two
    // implementations together: for random request mixes and lane
    // counts, the plan's group count must equal the live session's
    // iteration count and its cell count the session's active cells.
    // If the session's admission policy ever changes, this fails
    // loudly instead of letting the roofline model drift.
    let mut rng = Rng::new(0x9143);
    for case in 0..20 {
        let cfg = random_config(&mut rng);
        let seed = rng.next_u64();
        let lanes = 1 + rng.below(4);
        let n_requests = 1 + rng.below(5);
        let seg_counts: Vec<usize> = (0..n_requests).map(|_| 1 + rng.below(6)).collect();

        let mut backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let mut session = WavefrontSession::new(cfg.clone(), lanes);
        for (i, &s) in seg_counts.iter().enumerate() {
            let toks: Vec<u32> = (0..s * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
            session.submit(i as u64, &toks).unwrap();
        }
        session.run_to_completion(&mut backend).unwrap();
        let live = session.stats();

        let plan = Schedule::packed(&seg_counts, cfg.n_layers, lanes);
        assert_eq!(
            plan.group_count() as u64,
            live.launches,
            "case {case}: plan groups vs session iterations (lanes {lanes}, segs {seg_counts:?})"
        );
        assert_eq!(plan.cell_count() as u64, live.cells, "case {case}: cell totals");
    }
}

#[test]
fn p10_results_invariant_to_thread_count_and_scheduling_jitter() {
    let mut rng = Rng::new(0x10AD);
    for case in 0..6 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let lanes = 1 + rng.below(3);
        let n_requests = 2 + rng.below(3);
        let requests: Vec<Vec<u32>> = (0..n_requests)
            .map(|_| {
                let s = 1 + rng.below(4);
                let n = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
                (0..n).map(|_| rng.below(cfg.vocab) as u32).collect()
            })
            .collect();

        let run = |threads: usize, jitter_us: u64| {
            let mut backend =
                NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)).with_threads(threads);
            // Scheduling jitter: workers sleep a random 0..jitter_us
            // before each cell, scrambling completion order. Results
            // must not notice.
            backend.set_test_jitter(jitter_us);
            let mut session = WavefrontSession::new(cfg.clone(), lanes);
            for (i, toks) in requests.iter().enumerate() {
                session.submit(i as u64, toks).unwrap();
            }
            session.run_to_completion(&mut backend).unwrap();
            let mut outs = session.drain_completed();
            outs.sort_by_key(|o| o.id);
            outs
        };

        let reference = run(1, 0);
        for (threads, jitter_us) in [(2usize, 0u64), (2, 150), (5, 150)] {
            let outs = run(threads, jitter_us);
            assert_eq!(outs.len(), reference.len(), "case {case}");
            for (got, want) in outs.iter().zip(&reference) {
                assert_eq!(got.id, want.id, "case {case}");
                // Bit-identical logits, not approx-eq: a jittered
                // worker schedule must not change a single byte.
                assert_eq!(
                    got.logits, want.logits,
                    "case {case} req {} threads {threads} jitter {jitter_us}us cfg {cfg:?}",
                    got.id
                );
                assert_eq!(got.stats.launches, want.stats.launches, "case {case}");
                assert_eq!(got.stats.cells, want.stats.cells, "case {case}");
                assert_eq!(got.stats.slot_steps, want.stats.slot_steps, "case {case}");
                assert_eq!(got.stats.padded_cells, want.stats.padded_cells, "case {case}");
                assert_eq!(got.stats.tokens, want.stats.tokens, "case {case}");
            }
        }
    }
}

#[test]
fn p12_shard_plan_parity_over_random_workloads() {
    // For random model shapes, worker counts and layer splits, a shard
    // coordinator in front of in-process workers must produce outputs
    // identical to the one-process oracle: generated tokens and greedy
    // tails always, and in layer-split mode the raw per-segment logits
    // compared as f32 bit patterns over the wire (`logits_bits`).
    use diagonal_batching::config::ExecMode;
    use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
    use diagonal_batching::json::Value;
    use diagonal_batching::scheduler::StepBackend;
    use diagonal_batching::server::{Client, Server, ServerOptions};
    use diagonal_batching::shard::{CoordinatorOptions, ShardCoordinator};

    let mut rng = Rng::new(0x512D);
    for case in 0..5 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        // split ∈ 1..=L; worker count a random multiple of it (whole
        // chains). split == 1 exercises lane routing, > 1 the pipeline.
        let split = 1 + rng.below(cfg.n_layers);
        let n_workers = split * (1 + rng.below(2));

        let workers: Vec<Server> = (0..n_workers)
            .map(|_| {
                let engine = InferenceEngine::new(
                    NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
                    ExecMode::Diagonal,
                );
                let backend: Box<dyn StepBackend + Send> =
                    Box::new(NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)));
                Server::start_with(
                    engine,
                    "127.0.0.1:0",
                    8,
                    ServerOptions { shard_backend: Some(backend), ..ServerOptions::default() },
                )
                .unwrap()
            })
            .collect();
        let addrs: Vec<String> = workers.iter().map(|w| w.addr.to_string()).collect();
        let coord = ShardCoordinator::start(
            cfg.clone(),
            &addrs,
            "127.0.0.1:0",
            CoordinatorOptions { layer_split: split, ..CoordinatorOptions::default() },
        )
        .unwrap();
        let coord_addr = coord.addr.to_string();

        let n_requests = 1 + rng.below(3);
        for r in 0..n_requests {
            let s = 1 + rng.below(3);
            let n_tokens = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
            let prompt: Vec<u32> =
                (0..n_tokens).map(|_| rng.below(cfg.vocab) as u32).collect();
            let max_new = cfg.seg * (1 + rng.below(2));
            let sampled = rng.below(2) == 1;
            let want_logits = split > 1;

            let mut fields = vec![
                ("tokens", Value::arr_u32(&prompt)),
                ("max_new_tokens", Value::Num(max_new as f64)),
            ];
            if sampled {
                fields.push(("temperature", Value::Num(0.8)));
                fields.push(("seed", Value::Num((seed % 1000) as f64)));
            }
            if want_logits {
                fields.push(("want_logits", Value::Bool(true)));
            }
            let mut client = Client::connect(&coord_addr).unwrap();
            let done = client.request_stream(&Value::obj(fields), |_| {}).unwrap();

            let mut oracle = InferenceEngine::new(
                NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
                ExecMode::Sequential,
            );
            let mut req = GenerateRequest::new(1, prompt.clone()).generate(max_new);
            if sampled {
                req.sampling.temperature = 0.8;
                req.sampling.seed = seed % 1000;
            }
            req.want_logits = want_logits;
            let want = oracle.process(&req).unwrap();

            let ctx = format!(
                "case {case} req {r} split {split} workers {n_workers} sampled {sampled} cfg {cfg:?}"
            );
            assert_eq!(
                done.req("generated").unwrap().as_u32_vec().unwrap(),
                want.generated,
                "{ctx}"
            );
            let tail: Vec<usize> = done
                .req("greedy_tail")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect();
            assert_eq!(tail, want.greedy_tail, "{ctx}");

            if want_logits {
                // Bit-level gate: every computed segment's logits moved
                // through the pipeline as raw u32 patterns.
                let bits = done.req("logits_bits").unwrap().as_arr().unwrap();
                let oracle_logits = want.logits.as_ref().unwrap();
                assert_eq!(bits.len(), oracle_logits.len(), "segment count: {ctx}");
                for (s_i, (seg_bits, t)) in bits.iter().zip(oracle_logits).enumerate() {
                    let got: Vec<u32> = seg_bits.as_u32_vec().unwrap();
                    let expect: Vec<u32> =
                        t.data().iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, expect, "segment {s_i} logits bits: {ctx}");
                }
            }
        }

        let stats = coord.stats();
        assert_eq!(stats.shard_failovers.get(), 0, "case {case}: phantom failover");
        assert!(stats.shard_routed.get() + stats.shard_handoffs.get() > 0, "case {case}");
        coord.stop();
        for w in workers {
            w.stop();
        }
    }
}

#[test]
fn p13_weighted_fair_admission_is_starvation_free_and_bitexact() {
    use diagonal_batching::config::ExecMode;
    use diagonal_batching::coordinator::{Event, GenerateRequest, InferenceEngine, Response};
    use diagonal_batching::gateway::{FairScheduler, TenantSpec};
    use std::collections::HashMap;

    // Part 1a — no starvation across a flood. A batch-class tenant
    // (weight 0.25) backlogs 32 expensive jobs and the clock advances;
    // a late interactive job must be clamped to the current virtual
    // time and served within the next two pops, not after the flood.
    {
        let specs = vec![
            TenantSpec::parse("bulk:sk-b:batch").unwrap(),
            TenantSpec::parse("live:sk-l:interactive").unwrap(),
        ];
        let sched: FairScheduler<u64> = FairScheduler::new(specs, 64);
        for i in 0..32u64 {
            sched.push(1, 10.0, i).unwrap(); // tenant 1 = bulk (0 is local)
        }
        for _ in 0..5 {
            sched.try_pop().unwrap();
        }
        sched.push(2, 10.0, 100).unwrap();
        let next = [sched.try_pop().unwrap(), sched.try_pop().unwrap()];
        assert!(next.contains(&100), "late interactive job starved: popped {next:?}");
    }

    // Part 1b — weighted shares. Interactive (w=4) vs standard (w=1),
    // equal cost, both fully backlogged: every prefix of the pop order
    // must track the 4:1 ideal within one job (the SCFQ service bound).
    {
        let specs = vec![
            TenantSpec::parse("fast:sk-f:interactive").unwrap(),
            TenantSpec::parse("slow:sk-s:standard").unwrap(),
        ];
        let sched: FairScheduler<usize> = FairScheduler::new(specs, 64);
        for i in 0..40 {
            sched.push(1, 8.0, 1000 + i).unwrap();
            sched.push(2, 8.0, 2000 + i).unwrap();
        }
        let mut fast = 0usize;
        for k in 0..40usize {
            if sched.try_pop().unwrap() < 2000 {
                fast += 1;
            }
            let ideal = (k + 1) as f64 * 4.0 / 5.0;
            assert!(
                (fast as f64 - ideal).abs() <= 1.0 + 1e-9,
                "after {} pops: fast served {fast}, ideal {ideal}",
                k + 1
            );
        }
    }

    // Part 2 — random tenant mixes through `serve_queue`. One tenant is
    // deliberately flooded past its queue depth so some pushes shed
    // (clean "queue full" error, never a spin or a hang); every
    // ADMITTED request's response must be bit-identical to a solo
    // sequential run of the same request, at every worker-pool thread
    // count. Fair admission reorders requests, never arithmetic.
    let mut rng = Rng::new(0x13FA);
    for case in 0..4 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let classes = ["interactive", "standard", "batch"];
        let n_tenants = 1 + rng.below(3);
        let specs: Vec<TenantSpec> = (0..n_tenants)
            .map(|t| {
                let class = classes[rng.below(3)];
                TenantSpec::parse(&format!("t{t}:sk-{t}:{class}")).unwrap()
            })
            .collect();
        let depth = 2 + rng.below(3);

        // Random mix over all tenants (index 0 is the open local
        // tenant), then a flood: depth+2 one-segment jobs on one tenant
        // guarantees at least two deterministic sheds.
        let n_jobs = 3 + rng.below(4);
        let flood_tenant = rng.below(n_tenants + 1);
        let mut jobs: Vec<(usize, GenerateRequest)> = Vec::new();
        for i in 0..n_jobs + depth + 2 {
            let (tenant, s) = if i < n_jobs {
                (rng.below(n_tenants + 1), 1 + rng.below(3))
            } else {
                (flood_tenant, 1)
            };
            let n = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
            let prompt: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
            let mut req = GenerateRequest::new(i as u64, prompt);
            if rng.below(2) == 1 {
                req = req.generate(cfg.seg);
            }
            req.want_logits = true;
            jobs.push((tenant, req));
        }

        let run = |threads: usize| -> (Vec<u64>, HashMap<u64, Response>) {
            let sched: FairScheduler<(GenerateRequest, u64)> =
                FairScheduler::new(specs.clone(), depth);
            let mut shed = Vec::new();
            for (tenant, req) in &jobs {
                let cost = (req.prompt.len() + req.max_new_tokens) as f64;
                let id = req.id;
                if let Err(e) = sched.push(*tenant, cost, (req.clone(), id)) {
                    assert!(e.to_string().contains("queue full"), "case {case}: {e}");
                    shed.push(id);
                }
            }
            assert_eq!(sched.stats.shed.get(), shed.len() as u64, "case {case}");
            sched.close();

            let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed))
                .with_threads(threads);
            let mut e = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(2);
            let mut done: HashMap<u64, Response> = HashMap::new();
            e.serve_queue(&sched, |t, ev| match ev {
                Event::Done { stats } => {
                    done.insert(*t, *stats);
                }
                Event::Error { error } => panic!("case {case}: request {t} failed: {error}"),
                _ => {}
            })
            .unwrap();
            (shed, done)
        };

        let (shed_ref, done_ref) = run(1);
        assert!(!shed_ref.is_empty(), "case {case}: flood must shed");
        assert_eq!(
            shed_ref.len() + done_ref.len(),
            jobs.len(),
            "case {case}: every job either sheds at push or completes"
        );

        // Solo oracle: each admitted request alone on a fresh
        // sequential engine with the same weights.
        let mut oracle = InferenceEngine::new(
            NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
            ExecMode::Sequential,
        );
        for (_, req) in &jobs {
            let Some(got) = done_ref.get(&req.id) else { continue };
            let want = oracle.process(req).unwrap();
            let ctx = format!("case {case} req {} depth {depth} cfg {cfg:?}", req.id);
            assert_eq!(got.generated, want.generated, "{ctx}");
            assert_eq!(got.greedy_tail, want.greedy_tail, "{ctx}");
            let (a, b) = (got.logits.as_ref().unwrap(), want.logits.as_ref().unwrap());
            assert_eq!(a.len(), b.len(), "{ctx}");
            for (s_i, (x, y)) in a.iter().zip(b).enumerate() {
                let xb: Vec<u32> = x.data().iter().map(|v| v.to_bits()).collect();
                let yb: Vec<u32> = y.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(xb, yb, "segment {s_i}: {ctx}");
            }
        }

        // Thread-count invariance: identical shed set, identical
        // responses, bit for bit.
        for threads in [2usize, 4] {
            let (shed, done) = run(threads);
            assert_eq!(shed, shed_ref, "case {case} threads {threads}: shed set drifted");
            assert_eq!(done.len(), done_ref.len(), "case {case} threads {threads}");
            for (id, got) in &done {
                let want = &done_ref[id];
                let ctx = format!("case {case} req {id} threads {threads}");
                assert_eq!(got.generated, want.generated, "{ctx}");
                assert_eq!(got.greedy_tail, want.greedy_tail, "{ctx}");
                let (a, b) = (got.logits.as_ref().unwrap(), want.logits.as_ref().unwrap());
                assert_eq!(a.len(), b.len(), "{ctx}");
                for (s_i, (x, y)) in a.iter().zip(b).enumerate() {
                    let xb: Vec<u32> = x.data().iter().map(|v| v.to_bits()).collect();
                    let yb: Vec<u32> = y.data().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(xb, yb, "segment {s_i}: {ctx}");
                }
            }
        }
    }
}

#[test]
fn p14_selection_gates_exactly_the_planned_segments_at_every_thread_count() {
    use diagonal_batching::config::ExecMode;
    use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
    use diagonal_batching::quality::{self, OverflowPolicy};

    let mut rng = Rng::new(0x145E);
    let mut saw_skips = false;
    for case in 0..10 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let s = 2 + rng.below(8);
        let n = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
        let prompt: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();

        // The oracle plan: pure arithmetic over token ids, independent
        // of any engine or schedule.
        let planned =
            quality::plan_selection(&quality::segment_tokens(&prompt, cfg.seg))
                .iter()
                .filter(|&&skip| skip)
                .count();
        saw_skips |= planned > 0;

        let run = |threads: usize, policy: OverflowPolicy| {
            let backend =
                NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)).with_threads(threads);
            let mut e = InferenceEngine::new(backend, ExecMode::Diagonal);
            let mut req = GenerateRequest::new(1, prompt.clone()).with_overflow(policy);
            req.want_logits = true;
            e.process(&req).unwrap()
        };
        let bits = |r: &diagonal_batching::coordinator::Response| -> Vec<Vec<u32>> {
            r.logits
                .as_ref()
                .unwrap()
                .iter()
                .map(|t| t.data().iter().map(|x| x.to_bits()).collect())
                .collect()
        };

        let reference = run(1, OverflowPolicy::Select);
        assert_eq!(
            reference.segments_skipped, planned,
            "case {case}: engine gated {} segments, plan names {planned} (cfg {cfg:?})",
            reference.segments_skipped
        );
        assert!(!reference.overflow_routed, "case {case}: select must never re-route");

        for threads in [2usize, 4] {
            let got = run(threads, OverflowPolicy::Select);
            let ctx = format!("case {case} threads {threads} cfg {cfg:?}");
            assert_eq!(got.segments_skipped, planned, "{ctx}");
            assert_eq!(bits(&got), bits(&reference), "gated logits drifted: {ctx}");
            assert_eq!(
                got.saturation.to_bits(),
                reference.saturation.to_bits(),
                "saturation drifted: {ctx}"
            );
        }

        // A plan that names nothing means selection is a no-op: the run
        // must be bit-identical to policy-off.
        if planned == 0 {
            let off = run(1, OverflowPolicy::Off);
            assert_eq!(bits(&reference), bits(&off), "case {case}: empty plan must be a no-op");
        }
    }
    // The generator must actually exercise the gating path, not only
    // empty plans — otherwise the property above is vacuous.
    assert!(saw_skips, "no random case produced a non-empty selection plan");
}

#[test]
fn p6_minibatch_and_ideal_cover_all_cells() {
    let mut rng = Rng::new(0x3AD);
    for _ in 0..50 {
        let s = 1 + rng.below(20);
        let l = 1 + rng.below(8);
        let b = 1 + rng.below(8);
        let m = Schedule::minibatch(s, l, b);
        assert_eq!(m.cell_count(), s * l * b);
        let i = Schedule::ideal_even_load(s, l);
        assert_eq!(i.cell_count(), s * l);
        assert!(i.max_group() <= l);
    }
}
