//! Bit-exactness of the parallel cell pool: the pooled native backend
//! must produce byte-identical results to the sequential reference
//! oracle — outputs, memory states, and run stats — across layer
//! counts, lane counts, and thread counts, including ragged tails where
//! lanes finish out of step.
//!
//! "Byte-identical" is enforced literally: tensors are compared by
//! `f32::to_bits`, not by approximate equality, so a reordered
//! reduction, an FMA-contracted accumulation, or a NaN/-0.0 divergence
//! on any thread count fails loudly. This is the paper's exactness
//! claim (arXiv 2207.06881: the recurrence must stay exact) carried
//! into the actually-parallel runtime.

use diagonal_batching::config::ModelConfig;
use diagonal_batching::model::{default_threads, NativeBackend, Params};
use diagonal_batching::scheduler::{
    Executor, RunStats, ScheduleMode, StepBackend, WavefrontSession,
};
use diagonal_batching::tensor::{Rng, Tensor};

const LAYER_COUNTS: [usize; 3] = [1, 4, 12];
const LANE_COUNTS: [usize; 3] = [1, 3, 8];
const THREAD_COUNTS: [usize; 3] = [1, 2, 7];

/// Tiny model so the full {L} x {lanes} x {threads} grid stays fast in
/// debug builds; the math path is the same as any size.
fn cfg(n_layers: usize) -> ModelConfig {
    ModelConfig {
        name: format!("parity-l{n_layers}"),
        vocab: 32,
        d_model: 16,
        n_layers,
        n_heads: 2,
        d_ff: 24,
        seg: 4,
        mem: 2,
        k_assoc: 4,
        dpfp_nu: 2,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 8,
        phi_dim: 16,
        seg_total: 6,
    }
}

/// Strict byte equality — `to_bits`, not `==` (which would already
/// accept -0.0 == 0.0) and certainly not approx-eq.
fn assert_bits_eq(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
    }
}

/// The deterministic fields of [`RunStats`] (everything but wall time,
/// which is legitimately different across backends).
fn assert_stats_eq(a: &RunStats, b: &RunStats, ctx: &str) {
    assert_eq!(a.mode_diagonal, b.mode_diagonal, "{ctx}: mode");
    assert_eq!(a.segments, b.segments, "{ctx}: segments");
    assert_eq!(a.launches, b.launches, "{ctx}: launches");
    assert_eq!(a.cells, b.cells, "{ctx}: cells");
    assert_eq!(a.slot_steps, b.slot_steps, "{ctx}: slot_steps");
    assert_eq!(a.padded_cells, b.padded_cells, "{ctx}: padded_cells");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
}

/// Thread counts under test: the fixed {1, 2, 7} grid plus the
/// environment default, so the CI `PALLAS_THREADS=1` pass and the
/// default pass exercise different de-facto configurations.
fn thread_grid() -> Vec<usize> {
    let mut t = THREAD_COUNTS.to_vec();
    let d = default_threads();
    if !t.contains(&d) {
        t.push(d);
    }
    t
}

/// One grouped step over every (L, lanes, threads) combination: y, A',
/// z' must match the sequential oracle byte-for-byte, including frozen
/// masked slots.
#[test]
fn grouped_step_parity_grid() {
    for &l in &LAYER_COUNTS {
        for &lanes in &LANE_COUNTS {
            let c = cfg(l);
            let mut rng = Rng::new(0xA11 + (l * 31 + lanes) as u64);
            let x = Tensor::randn(&[l, lanes, c.seg_total, c.d_model], 0.5, &mut rng);
            let a = Tensor::randn(&[l, lanes, c.d_model, c.phi_dim], 0.1, &mut rng);
            let z = Tensor::randn(&[l, lanes, c.phi_dim], 0.1, &mut rng);
            // Ragged occupancy: mask out a deterministic scatter of
            // slots (never all of them).
            let mut mask = vec![1.0f32; l * lanes];
            for (i, m) in mask.iter_mut().enumerate() {
                if i % 5 == 3 && i + 1 < l * lanes {
                    *m = 0.0;
                }
            }

            let mut oracle = NativeBackend::new(c.clone(), Params::random(&c, 77));
            let (y1, a1, z1) = oracle.grouped_step(&x, &a, &z, &mask).unwrap();

            for &threads in &thread_grid() {
                let ctx = format!("L={l} lanes={lanes} threads={threads}");
                let mut pooled =
                    NativeBackend::new(c.clone(), Params::random(&c, 77)).with_threads(threads);
                let (y2, a2, z2) = pooled.grouped_step(&x, &a, &z, &mask).unwrap();
                assert_bits_eq(&y1, &y2, &format!("{ctx}: y"));
                assert_bits_eq(&a1, &a2, &format!("{ctx}: memory A"));
                assert_bits_eq(&z1, &z2, &format!("{ctx}: memory z"));
            }
        }
    }
}

/// Full packed sessions over the grid, with ragged tails so lanes
/// finish out of step: logits and per-request RunStats must be
/// identical to the single-threaded session, and the logits must also
/// match each request run alone through the sequential executor.
#[test]
fn session_parity_grid_with_ragged_tails() {
    for &l in &LAYER_COUNTS {
        for &lanes in &LANE_COUNTS {
            let c = cfg(l);
            // lanes + 2 requests so at least one waits in the backlog;
            // lengths vary and most have a ragged (padded) tail.
            let requests: Vec<Vec<u32>> = (0..lanes + 2)
                .map(|i| {
                    let segs = 1 + i % 4;
                    let ragged = i % 3; // 0..=2 tokens short of full
                    let n = (segs * c.seg).saturating_sub(ragged).max(1);
                    (0..n as u32).map(|t| (t * 7 + i as u32) % c.vocab as u32).collect()
                })
                .collect();

            let run_session = |threads: usize| {
                let mut backend =
                    NativeBackend::new(c.clone(), Params::random(&c, 123)).with_threads(threads);
                let mut session = WavefrontSession::new(c.clone(), lanes);
                for (i, toks) in requests.iter().enumerate() {
                    session.submit(i as u64, toks).unwrap();
                }
                session.run_to_completion(&mut backend).unwrap();
                let mut outs = session.drain_completed();
                outs.sort_by_key(|o| o.id);
                outs
            };

            let reference = run_session(1);
            assert_eq!(reference.len(), requests.len());

            for &threads in &thread_grid() {
                if threads == 1 {
                    continue;
                }
                let ctx = format!("L={l} lanes={lanes} threads={threads}");
                let outs = run_session(threads);
                assert_eq!(outs.len(), reference.len(), "{ctx}: completion count");
                for (got, want) in outs.iter().zip(&reference) {
                    assert_eq!(got.id, want.id, "{ctx}: completion id");
                    assert_eq!(got.logits.len(), want.logits.len(), "{ctx}: segments");
                    for (s, (ga, wa)) in got.logits.iter().zip(&want.logits).enumerate() {
                        assert_bits_eq(ga, wa, &format!("{ctx}: req {} seg {s}", got.id));
                    }
                    assert_stats_eq(&got.stats, &want.stats, &format!("{ctx}: req {}", got.id));
                }
            }

            // The single-threaded session itself must match the solo
            // sequential executor (ties this suite to proptest P7).
            for (i, toks) in requests.iter().enumerate() {
                let mut b = NativeBackend::new(c.clone(), Params::random(&c, 123));
                let want = Executor::new(&mut b, ScheduleMode::Sequential).run(toks).unwrap();
                for (s, (ga, wa)) in
                    reference[i].logits.iter().zip(&want.logits).enumerate()
                {
                    assert_bits_eq(
                        ga,
                        wa,
                        &format!("L={l} lanes={lanes}: req {i} seg {s} vs sequential"),
                    );
                }
            }
        }
    }
}

/// The diagonal executor (the single-request special case) is
/// thread-count-invariant too, including S < L ramp-only wavefronts.
#[test]
fn executor_diagonal_parity_across_threads() {
    for &l in &LAYER_COUNTS {
        let c = cfg(l);
        for n_segments in [1usize, 2, 5] {
            let toks: Vec<u32> =
                (0..n_segments * c.seg - 1).map(|t| (t * 3 + 1) as u32 % c.vocab as u32).collect();
            let mut b1 = NativeBackend::new(c.clone(), Params::random(&c, 5));
            let seq = Executor::new(&mut b1, ScheduleMode::Sequential).run(&toks).unwrap();
            for &threads in &thread_grid() {
                let mut b2 =
                    NativeBackend::new(c.clone(), Params::random(&c, 5)).with_threads(threads);
                let diag = Executor::new(&mut b2, ScheduleMode::Diagonal).run(&toks).unwrap();
                assert_eq!(seq.segments(), diag.segments());
                for (s, (a, b)) in seq.logits.iter().zip(&diag.logits).enumerate() {
                    assert_bits_eq(
                        a,
                        b,
                        &format!("L={l} S={n_segments} threads={threads} seg {s}"),
                    );
                }
            }
        }
    }
}
