//! Integration tests for the pallas-bench harness: the suite registry
//! runs real suites end to end, the resulting `BENCH_*.json` report
//! round-trips through `src/json.rs`, and the baseline comparison gates
//! regressions.
//!
//! Everything here runs artifact-free: simulated suites fall back to
//! the built-in paper configs, serving suites use the native backend,
//! and HLO suites report `skipped` (which must still appear in the
//! report — the schema covers every selected suite).

use diagonal_batching::bench::{
    compare, glob_match, run_matching, BenchReport, BenchSettings, SuiteStatus,
};
use diagonal_batching::json::Value;

/// Fast settings pointed at a manifest path that never exists, so the
/// run is fully deterministic regardless of local artifacts.
fn artifact_free_settings() -> BenchSettings {
    BenchSettings {
        manifest_path: "artifacts/definitely-not-here.json".to_string(),
        fast: true,
        ..BenchSettings::default()
    }
}

#[test]
fn fig_suites_run_artifact_free_and_roundtrip() {
    let report = run_matching("fig*", &artifact_free_settings());

    // Every fig suite is simulated (fig4 additionally measures the CPU
    // analog) — all must run and pass with zero artifacts.
    let names: Vec<&str> = report.suites.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(
        names,
        ["fig1_headline", "fig4_grouped_gemm", "fig5_attention", "fig6_diag_vs_minibatch"]
    );
    for s in &report.suites {
        assert_eq!(s.status, SuiteStatus::Ok, "{}: {}", s.name, s.detail);
        assert!(!s.metrics.is_empty(), "{} recorded no metrics", s.name);
    }
    assert!(report.all_passed());

    // Run metadata is populated.
    assert!(!report.meta.git_sha.is_empty());
    assert_eq!(report.meta.device, "A100-80G");
    assert!(report.meta.fast);
    assert!(report.meta.peak_tflops > 0.0);

    // serialize -> parse -> deserialize is lossless (src/json.rs).
    let text = report.to_json().to_json();
    let back = BenchReport::from_json(&Value::parse(&text).unwrap()).unwrap();
    assert_eq!(back, report);
}

#[test]
fn hlo_suites_skip_cleanly_but_stay_in_the_report() {
    let report = run_matching("table2_error", &artifact_free_settings());
    assert_eq!(report.suites.len(), 1);
    let s = &report.suites[0];
    assert_eq!(s.status, SuiteStatus::Skipped);
    assert!(s.detail.contains("not found"), "skip reason: {}", s.detail);
    // A skip is not a failure: the run stays green.
    assert!(report.all_passed());
}

#[test]
fn serve_suites_measure_the_native_engine() {
    // The in-process serving suites only: `shard_scaling` and
    // `gateway_fairness` also carry the `serve` tag but bind real TCP
    // sockets / spawn servers, so they run in their own CI bench steps
    // rather than inside this unit test.
    let report = run_matching(
        "throughput_packed,serve_latency,serve_generate,cache_reuse",
        &artifact_free_settings(),
    );
    let names: Vec<&str> = report.suites.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, ["throughput_packed", "serve_latency", "serve_generate", "cache_reuse"]);
    for s in &report.suites {
        assert_eq!(s.status, SuiteStatus::Ok, "{}: {}", s.name, s.detail);
    }
    // The cache suite's hard gates ran green; its hit-rate metric is a
    // full sweep (every shared-prefix client hit).
    let cache = &report.suites[3];
    let hit_rate = cache.metrics.iter().find(|m| m.name == "cache_hit_rate").unwrap();
    assert!(hit_rate.value > 0.0, "cache_hit_rate {}", hit_rate.value);
    let saved = cache.metrics.iter().find(|m| m.name == "prefill_cells_saved_frac").unwrap();
    assert!(saved.value > 0.0, "prefill_cells_saved_frac {}", saved.value);
    let serve = &report.suites[1];
    for metric in ["latency_ms_p50", "latency_ms_p90", "latency_ms_p99", "mean_group"] {
        assert!(
            serve.metrics.iter().any(|m| m.name == metric),
            "serve_latency missing metric {metric}"
        );
    }
    // Packing >= 2 lanes must beat the solo-diagonal mean group bound
    // (L = 4, S = 6 per request => S*L/(S+L-1) ~ 2.67).
    let mg = serve.metrics.iter().find(|m| m.name == "mean_group").unwrap();
    assert!(mg.value > 2.67, "mean_group {}", mg.value);
}

#[test]
fn tag_and_glob_selection() {
    // Selecting by tag: every suite tagged `table`.
    let report = run_matching("table", &artifact_free_settings());
    assert!(report.suites.iter().all(|s| s.tags.iter().any(|t| t == "table")));
    assert_eq!(report.suites.len(), 7);
    // Nothing matches a bogus pattern.
    assert!(run_matching("no_such_suite_*", &artifact_free_settings()).suites.is_empty());
    // The CLI's comma-separated form.
    assert!(glob_match("fig*,table*", "table9_vs_armt"));
}

#[test]
fn regression_gate_verdict_end_to_end() {
    // Run one deterministic suite twice: identical reports must pass the
    // gate; a slowed-down mutant must fail it.
    let settings = artifact_free_settings();
    let baseline = run_matching("fig1_headline", &settings);
    let current = run_matching("fig1_headline", &settings);
    let ok = compare(&baseline, &current, 1.15);
    assert!(ok.passed(), "identical runs must pass: {:?}", ok.regressions);
    assert!(ok.compared > 0, "gate must actually compare something");

    let mut slowed = current.clone();
    for m in &mut slowed.suites[0].metrics {
        use diagonal_batching::bench::report::Better;
        match m.better {
            Better::Lower => m.value *= 1.5,  // modeled seconds got worse
            Better::Higher => m.value /= 1.5, // speedups got worse
            Better::Info => {}
        }
    }
    let bad = compare(&baseline, &slowed, 1.15);
    assert!(!bad.passed());
    assert!(bad.regressions.len() >= 2, "both directions must gate");
}

#[test]
fn report_survives_disk_roundtrip() {
    let report = run_matching("fig5_attention", &artifact_free_settings());
    let path = std::env::temp_dir().join(format!("BENCH_test_{}.json", std::process::id()));
    report.save(&path).unwrap();
    let back = BenchReport::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back, report);
}
