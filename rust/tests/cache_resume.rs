//! Memory-state snapshot store: the resume-exactness gate.
//!
//! The load-bearing invariant of the `cache` subsystem, tested like
//! the packing (P7), jitter (P10) and decode-exactness contracts
//! before it:
//!
//!  * P11: for random workloads, suspend-after-segment-k then
//!    resume-and-continue is BIT-IDENTICAL (`f32::to_bits`) to the
//!    straight-through run — for all k, across worker-pool thread
//!    counts {1, N}, with the snapshot pushed through its JSON
//!    serialization, and with the resumed request packed into ragged
//!    multi-lane sessions next to unrelated traffic.
//!  * The engine-level acceptance gate: a generation resumed from a
//!    `MemSnapshot` — via an in-memory prefix-cache hit AND via a disk
//!    round-trip — produces byte-identical tokens and logits to the
//!    sequential full-recompute oracle, while executing strictly fewer
//!    prefill cells than the cold run.

use diagonal_batching::cache::MemSnapshot;
use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
use diagonal_batching::json::Value;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::scheduler::{
    segment_tokens, Executor, ScheduleMode, WavefrontSession,
};
use diagonal_batching::tensor::{Rng, Tensor};

fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(3);
    let head_dim = [4usize, 8][rng.below(2)];
    let d_model = n_heads * head_dim;
    let k_assoc = [4usize, 8][rng.below(2)];
    let nu = 1 + rng.below(3);
    let seg = 4 + rng.below(8);
    let mem = 1 + rng.below(4);
    let n_layers = 1 + rng.below(4);
    ModelConfig {
        name: "prop".into(),
        vocab: 32 + rng.below(64),
        d_model,
        n_layers,
        n_heads,
        d_ff: d_model * 2,
        seg,
        mem,
        k_assoc,
        dpfp_nu: nu,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim,
        phi_dim: 2 * nu * k_assoc,
        seg_total: seg + mem,
    }
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

/// Run `prefix` through a throwaway 1-lane session, returning the
/// captured post-prefix snapshot AFTER a JSON round-trip — every
/// resumed byte in these tests has survived serialization.
fn suspend_after(backend: &mut NativeBackend, prefix: &[Vec<u32>]) -> MemSnapshot {
    let cfg = backend.config().clone();
    let mut session = WavefrontSession::new(cfg, 1);
    session.submit_stream(99, prefix.to_vec(), false).unwrap();
    session.capture_after(99, prefix.len() - 1).unwrap();
    session.finish_stream(99).unwrap();
    let mut snap = None;
    while session.step(backend).unwrap() {
        while let Some(exit) = session.pop_exited() {
            if let Some(s) = exit.snapshot {
                snap = Some(s);
            }
        }
    }
    let snap = snap.expect("prefix snapshot delivered");
    let round_tripped =
        MemSnapshot::from_json(&Value::parse(&snap.to_json().to_json()).unwrap()).unwrap();
    assert_eq!(round_tripped, snap, "serialization must be lossless");
    round_tripped
}

#[test]
fn p11_suspend_resume_bitexact_for_all_k_threads_and_lanes() {
    let mut rng = Rng::new(0xCAC4E);
    for case in 0..6 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let s = 2 + rng.below(5);
        let n_tokens = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
        let tokens: Vec<u32> = (0..n_tokens).map(|_| rng.below(cfg.vocab) as u32).collect();
        let segments = segment_tokens(&cfg, &tokens).unwrap();
        let lanes = 1 + rng.below(3);
        let other_s = 1 + rng.below(4);
        let other: Vec<u32> = (0..other_s * cfg.seg - rng.below(cfg.seg.min(3)))
            .map(|_| rng.below(cfg.vocab) as u32)
            .collect();

        // Straight-through reference (the sequential oracle).
        let mut b = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let reference = Executor::new(&mut b, ScheduleMode::Sequential).run(&tokens).unwrap();
        let other_ref = Executor::new(&mut b, ScheduleMode::Sequential).run(&other).unwrap();

        for threads in [1usize, 3] {
            let mut backend =
                NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)).with_threads(threads);
            for k in 1..segments.len() {
                let snap = suspend_after(&mut backend, &segments[..k]);
                assert_eq!(snap.segments, k);

                // Resume packed into a ragged multi-lane session next
                // to an unrelated request.
                let mut session = WavefrontSession::new(cfg.clone(), lanes);
                session
                    .submit_stream_resumed(1, snap, segments[k..].to_vec(), true)
                    .unwrap();
                session.finish_stream(1).unwrap();
                session.submit(2, &other).unwrap();
                session.run_to_completion(&mut backend).unwrap();
                let mut outs = session.drain_completed();
                outs.sort_by_key(|o| o.id);
                assert_eq!(outs.len(), 2, "case {case} k {k} threads {threads}");

                assert_eq!(
                    outs[0].logits.len(),
                    segments.len() - k,
                    "case {case} k {k}: only the remaining segments are computed"
                );
                for (i, (got, want)) in
                    outs[0].logits.iter().zip(&reference.logits[k..]).enumerate()
                {
                    assert_eq!(
                        bits(got),
                        bits(want),
                        "case {case} k {k} threads {threads} lanes {lanes} segment {i} \
                         cfg {cfg:?}"
                    );
                }
                for (i, (got, want)) in
                    outs[1].logits.iter().zip(&other_ref.logits).enumerate()
                {
                    assert_eq!(
                        bits(got),
                        bits(want),
                        "case {case} k {k}: concurrent request perturbed, segment {i}"
                    );
                }
            }
        }
    }
}

fn engine(seed: u64, mode: ExecMode) -> InferenceEngine<NativeBackend> {
    let cfg = ModelConfig::synthetic();
    InferenceEngine::new(NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)), mode)
}

fn prompt_of(n: usize, salt: u32) -> Vec<u32> {
    let vocab = ModelConfig::synthetic().vocab as u32;
    (0..n as u32).map(|i| (i * 29 + salt) % vocab).collect()
}

/// The acceptance gate, part 1: an in-memory prefix-cache hit resumes
/// bit-identically to the sequential full-recompute oracle and
/// executes strictly fewer prefill cells than the cold run.
#[test]
fn acceptance_prefix_hit_matches_sequential_oracle() {
    let cfg = ModelConfig::synthetic();
    let seg = cfg.seg;
    let shared = prompt_of(seg * 5, 3);
    let mut tail = shared.clone();
    tail.extend(prompt_of(seg * 2, 17));

    // Sequential full-recompute oracle.
    let mut oracle = engine(7, ExecMode::Sequential);
    let mut want_req = GenerateRequest::new(1, tail.clone()).generate(2 * seg);
    want_req.want_logits = true;
    let want = oracle.process(&want_req).unwrap();

    // Cold diagonal run (cells baseline), then a warm engine: first
    // request seeds the store, second hits it.
    let mut cold = engine(7, ExecMode::Diagonal);
    let cold_resp = cold.process(&want_req).unwrap();

    let mut warm = engine(7, ExecMode::Diagonal).with_cache_bytes(1 << 22);
    warm.process(&GenerateRequest::new(2, shared)).unwrap();
    let mut hit_req = GenerateRequest::new(3, tail).generate(2 * seg);
    hit_req.want_logits = true;
    let hit = warm.process(&hit_req).unwrap();

    assert_eq!(hit.reused_segments, 5, "the shared prefix came from the cache");
    assert_eq!(warm.stats.cache_hits.get(), 1);
    assert!(
        hit.stats.cells < cold_resp.stats.cells,
        "hit must execute strictly fewer prefill cells ({} vs {})",
        hit.stats.cells,
        cold_resp.stats.cells
    );

    // Byte-identical tokens and logits vs the oracle.
    assert_eq!(hit.generated, want.generated);
    assert_eq!(hit.greedy_tail, want.greedy_tail);
    let (hl, wl) = (hit.logits.unwrap(), want.logits.unwrap());
    assert_eq!(hl.len() + 5, wl.len());
    for (got, want) in hl.iter().zip(&wl[5..]) {
        assert_eq!(bits(got), bits(want));
    }
}

/// The acceptance gate, part 2: a disk round-trip — suspend to a file,
/// load it back, resume — is byte-identical to recomputing the full
/// history through the sequential oracle.
#[test]
fn acceptance_disk_roundtrip_matches_sequential_oracle() {
    let cfg = ModelConfig::synthetic();
    let seg = cfg.seg;
    let turn1 = prompt_of(seg * 3, 5);
    let turn2 = prompt_of(seg, 23);

    let mut e = engine(11, ExecMode::Diagonal);
    // generate(2 * seg): one decode segment is fed back, so the saved
    // history is 3 prompt + 1 decode segments.
    let resp1 = e.process(&GenerateRequest::new(1, turn1.clone()).generate(2 * seg).with_save())
        .unwrap();
    let snap = resp1.final_state.expect("saved conversation");
    assert_eq!(snap.segments, 4);

    let path = std::env::temp_dir().join(format!("cache_resume_{}.json", std::process::id()));
    snap.save(&path).unwrap();
    let restored = MemSnapshot::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(restored, snap, "disk round-trip is lossless");

    // Resume from disk on a FRESH engine with the same weights; the
    // pooled backend variant must agree byte-for-byte too.
    for threads in [1usize, 3] {
        let cfg = ModelConfig::synthetic();
        let backend =
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 11)).with_threads(threads);
        let mut fresh = InferenceEngine::new(backend, ExecMode::Diagonal);
        let mut r2 = GenerateRequest::new(2, turn2.clone())
            .generate(seg)
            .resume_snapshot(restored.clone());
        r2.want_logits = true;
        let resp2 = fresh.process(&r2).unwrap();
        assert_eq!(resp2.reused_segments, 4, "zero history re-prefill");

        // Oracle: the full history recomputed straight through.
        let mut full = turn1.clone();
        full.extend_from_slice(&resp1.generated[..seg]); // the fed decode segment
        full.extend_from_slice(&turn2);
        let mut oracle = engine(11, ExecMode::Sequential);
        let mut ro = GenerateRequest::new(3, full).generate(seg);
        ro.want_logits = true;
        let want = oracle.process(&ro).unwrap();

        assert_eq!(resp2.generated, want.generated, "threads {threads}");
        assert_eq!(resp2.greedy_tail, want.greedy_tail);
        let (gl, wl) = (resp2.logits.unwrap(), want.logits.unwrap());
        assert_eq!(gl.len() + 4, wl.len());
        for (got, want) in gl.iter().zip(&wl[4..]) {
            assert_eq!(bits(got), bits(want), "threads {threads}");
        }
    }
}

/// Sequential-mode resume is the same exactness contract through the
/// second, independent implementation of the recurrence.
#[test]
fn sequential_resume_matches_diagonal_resume() {
    let cfg = ModelConfig::synthetic();
    let seg = cfg.seg;
    let history = prompt_of(seg * 4, 9);
    let fresh_tokens = prompt_of(seg, 31);

    let mut e = engine(13, ExecMode::Diagonal);
    let saved = e
        .process(&GenerateRequest::new(1, history).with_save())
        .unwrap()
        .final_state
        .unwrap();

    let mut run = |mode: ExecMode| {
        let mut r = GenerateRequest::new(9, fresh_tokens.clone())
            .generate(seg)
            .resume_snapshot(saved.clone());
        r.mode = Some(mode);
        r.want_logits = true;
        engine(13, mode).process(&r).unwrap()
    };
    let diag = run(ExecMode::Diagonal);
    let sequential = run(ExecMode::Sequential);
    assert_eq!(diag.generated, sequential.generated);
    let (dl, sl) = (diag.logits.unwrap(), sequential.logits.unwrap());
    assert_eq!(dl.len(), sl.len());
    for (a, b) in dl.iter().zip(&sl) {
        assert_eq!(bits(a), bits(b));
    }
}

/// Eviction safety: once the LRU budget evicts a prefix, requests fall
/// back to a cold prefill with identical results.
#[test]
fn eviction_falls_back_to_cold_prefill_exactly() {
    let cfg = ModelConfig::synthetic();
    let seg = cfg.seg;
    let prompt = prompt_of(seg * 4, 2);
    let mut want_req = GenerateRequest::new(1, prompt.clone());
    want_req.want_logits = true;

    let mut plain = engine(17, ExecMode::Diagonal);
    let want = plain.process(&want_req).unwrap();

    // A budget too small for even one snapshot: every insert evicts
    // itself, every lookup misses — behavior must match no-cache runs.
    let mut tiny = engine(17, ExecMode::Diagonal).with_cache_bytes(64);
    for round in 0..3 {
        let resp = tiny.process(&want_req).unwrap();
        assert_eq!(resp.reused_segments, 0, "round {round}: nothing to reuse");
        assert_eq!(
            bits(&resp.logits.clone().unwrap()[0]),
            bits(&want.logits.as_ref().unwrap()[0])
        );
    }
    assert_eq!(tiny.stats.cache_hits.get(), 0);
    assert!(tiny.stats.cache_evictions.get() > 0, "budget must have evicted");
    assert_eq!(tiny.stats.cache_bytes.get(), 0);
}
