//! HLO-backend integration tests: the AOT artifacts vs the native rust
//! oracle, and the paper's Table 2 error-accumulation experiment on the
//! real PJRT execution path.
//!
//! All tests skip gracefully when `artifacts/manifest.json` is absent
//! (run `make artifacts` first); CI-style runs get the full coverage.

use diagonal_batching::config::{ExecMode, Manifest};
use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::scheduler::{Executor, ScheduleMode, StepBackend};
use diagonal_batching::tensor::{Rng, Tensor};

fn manifest() -> Option<Manifest> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
    std::path::Path::new(path).exists().then(|| Manifest::load(path).unwrap())
}

fn tokens(n: usize, vocab: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(vocab) as u32).collect()
}

#[test]
fn hlo_grouped_step_matches_native_oracle() {
    let Some(m) = manifest() else { return };
    let mut hlo = HloBackend::load(&m, "tiny").unwrap();
    let cfg = hlo.config().clone();
    let params = Params::load(&m, "tiny").unwrap();
    let mut native = NativeBackend::new(cfg.clone(), params);

    let mut rng = Rng::new(3);
    let l = cfg.n_layers;
    let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
    let a = Tensor::randn(&[l, cfg.d_model, cfg.phi_dim], 0.05, &mut rng);
    let z = Tensor::randn(&[l, cfg.phi_dim], 0.05, &mut rng);
    let mask = vec![1.0; l];

    let (yh, ah, zh) = hlo.grouped_step(&x, &a, &z, &mask).unwrap();
    let (yn, an, zn) = native.grouped_step(&x, &a, &z, &mask).unwrap();
    assert!(yh.rel_error(&yn) < 2e-3, "y rel {}", yh.rel_error(&yn));
    assert!(ah.rel_error(&an) < 2e-3, "A rel {}", ah.rel_error(&an));
    assert!(zh.rel_error(&zn) < 2e-3, "z rel {}", zh.rel_error(&zn));
}

#[test]
fn hlo_masked_slots_bit_frozen() {
    // The artifact contract: state rows with mask 0 come back UNTOUCHED.
    let Some(m) = manifest() else { return };
    let mut hlo = HloBackend::load(&m, "tiny").unwrap();
    let cfg = hlo.config().clone();
    let mut rng = Rng::new(4);
    let l = cfg.n_layers;
    let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
    let a = Tensor::randn(&[l, cfg.d_model, cfg.phi_dim], 0.05, &mut rng);
    let z = Tensor::randn(&[l, cfg.phi_dim], 0.05, &mut rng);
    let mut mask = vec![1.0; l];
    mask[1] = 0.0;
    mask[l - 1] = 0.0;
    let (_, ah, zh) = hlo.grouped_step(&x, &a, &z, &mask).unwrap();
    for i in [1, l - 1] {
        assert_eq!(ah.index0(i), a.index0(i), "A slot {i} must be frozen");
        assert_eq!(zh.index0(i), z.index0(i), "z slot {i} must be frozen");
    }
    // active slots must move
    assert!(ah.index0(0).rel_error(&a.index0(0)) > 1e-6);
}

#[test]
fn hlo_embed_lm_head_match_native() {
    let Some(m) = manifest() else { return };
    let mut hlo = HloBackend::load(&m, "tiny").unwrap();
    let cfg = hlo.config().clone();
    let params = Params::load(&m, "tiny").unwrap();
    let mut native = NativeBackend::new(cfg.clone(), params);

    let toks = tokens(cfg.seg, cfg.vocab, 5);
    let xh = hlo.embed(&toks).unwrap();
    let xn = native.embed(&toks).unwrap();
    assert!(xh.rel_error(&xn) < 1e-5, "embed rel {}", xh.rel_error(&xn));

    let lh = hlo.lm_head(&xh).unwrap();
    let ln = native.lm_head(&xn).unwrap();
    assert_eq!(lh.shape(), &[cfg.seg, cfg.vocab]);
    assert!(lh.rel_error(&ln) < 1e-3, "lm_head rel {}", lh.rel_error(&ln));
}

#[test]
fn hlo_end_to_end_matches_native_oracle() {
    let Some(m) = manifest() else { return };
    let mut hlo = HloBackend::load(&m, "tiny").unwrap();
    let cfg = hlo.config().clone();
    let toks = tokens(cfg.seg * 3, cfg.vocab, 6);

    let out_h = Executor::new(&mut hlo, ScheduleMode::Diagonal).run(&toks).unwrap();
    let params = Params::load(&m, "tiny").unwrap();
    let mut native = NativeBackend::new(cfg, params);
    let out_n = Executor::new(&mut native, ScheduleMode::Diagonal).run(&toks).unwrap();

    assert_eq!(out_h.segments(), out_n.segments());
    let sh = out_h.stacked().unwrap();
    let sn = out_n.stacked().unwrap();
    let rel = sh.rel_error(&sn);
    assert!(rel < 5e-3, "end-to-end rel {rel}");
    // greedy decodes agree almost everywhere
    let (ah, an) = (sh.argmax_rows(), sn.argmax_rows());
    let agree = ah.iter().zip(&an).filter(|(x, y)| x == y).count() as f64 / ah.len() as f64;
    assert!(agree > 0.99, "argmax agreement {agree}");
}

#[test]
fn table2_error_accumulation_under_2_percent() {
    // The paper's Table 2: relative Frobenius drift between the diagonal
    // and sequential executions stays < 2% as segments accumulate.
    let Some(m) = manifest() else { return };
    let mut hlo = HloBackend::load(&m, "tiny").unwrap();
    let cfg = hlo.config().clone();
    for n_segments in [1usize, 2, 4, 8] {
        let toks = tokens(cfg.seg * n_segments, cfg.vocab, 7 + n_segments as u64);
        let d = Executor::new(&mut hlo, ScheduleMode::Diagonal).run(&toks).unwrap();
        let s = Executor::new(&mut hlo, ScheduleMode::Sequential).run(&toks).unwrap();
        let rel = d.stacked().unwrap().rel_error(&s.stacked().unwrap());
        assert!(rel < 0.02, "S={n_segments}: rel {rel}");
    }
}

#[test]
fn full_attention_bucket_execution() {
    let Some(m) = manifest() else { return };
    let mut hlo = HloBackend::load(&m, "tiny").unwrap();
    let cfg = hlo.config().clone();
    let toks = tokens(100, cfg.vocab, 8); // pads into the 128 bucket
    let out = hlo.full_attn(&toks).unwrap();
    assert_eq!(out.shape(), &[100, cfg.vocab]);

    // against the native oracle
    let params = Params::load(&m, "tiny").unwrap();
    let native = NativeBackend::new(cfg, params);
    let want = native.full_attn_forward(&toks).unwrap();
    let rel = out.rel_error(&want);
    assert!(rel < 2e-3, "full-attn rel {rel}");
}

#[test]
fn grouped_step_bwd_runs_and_matches_shapes() {
    // Training support (paper Appendix A): the backward executable
    // produces gradients with the primal shapes.
    let Some(m) = manifest() else { return };
    let mut hlo = HloBackend::load(&m, "toy").unwrap();
    let cfg = hlo.config().clone();
    let mut rng = Rng::new(9);
    let l = cfg.n_layers;
    let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
    let a = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
    let z = Tensor::zeros(&[l, cfg.phi_dim]);
    let mask = vec![1.0; l];
    let dy = Tensor::full(&[l, cfg.seg_total, cfg.d_model], 1.0);
    let da = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
    let dz = Tensor::zeros(&[l, cfg.phi_dim]);

    let grads = hlo.grouped_step_bwd(&x, &a, &z, &mask, &dy, &da, &dz).unwrap();
    assert_eq!(grads.len(), 3 + 13, "dx, dA, dz + 13 param grads");
    assert_eq!(grads[0].shape(), x.shape());
    assert_eq!(grads[1].shape(), a.shape());
    assert_eq!(grads[2].shape(), z.shape());
    // gradient w.r.t. x is nonzero
    assert!(grads[0].norm() > 0.0);
}

#[test]
fn engine_auto_mode_on_hlo_backend() {
    let Some(m) = manifest() else { return };
    let backend = HloBackend::load(&m, "micro").unwrap();
    let mut engine = InferenceEngine::new(backend, ExecMode::Auto);
    let cal = engine.calibrate(3).unwrap();
    assert!(cal.grouped_step_s > 0.0 && cal.single_step_s > 0.0);
    let vocab = engine.config().vocab;
    let seg = engine.config().seg;
    // well past the measured micro crossover (~50-70 segments on this
    // testbed): the calibrated policy must pick diagonal
    let long = tokens(seg * 160, vocab, 10);
    let resp = engine.process(&GenerateRequest::new(1, long)).unwrap();
    assert_eq!(resp.mode_used, ExecMode::Diagonal);
    // and far below it: sequential
    let short = tokens(seg, vocab, 11);
    let resp = engine.process(&GenerateRequest::new(2, short)).unwrap();
    assert_eq!(resp.mode_used, ExecMode::Sequential);
}
