//! Off-policy invariance of the quality tier.
//!
//! The memory-saturation monitor runs on every request — there is no
//! way to turn it off. These tests pin the tier's core contract: with
//! `overflow` unset (the default `off` policy) the monitor is
//! observation-only, so every output byte is identical to the
//! pre-quality-tier engine — across worker thread counts, across packed
//! wavefront lanes, and against the sequential oracle. The saturation
//! *measurement* itself must also be deterministic: the energy signals
//! are accumulated in fixed slot order on the engine thread, so the
//! reported value is bit-identical at every thread count.

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{
    Event, GenerateRequest, InferenceEngine, RequestQueue, Response,
};
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::quality::OverflowPolicy;
use diagonal_batching::tensor::Rng;

fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(3);
    let head_dim = [4usize, 8][rng.below(2)];
    let d_model = n_heads * head_dim;
    let k_assoc = [4usize, 8][rng.below(2)];
    let nu = 1 + rng.below(3);
    let seg = 4 + rng.below(8);
    let mem = 1 + rng.below(4);
    let n_layers = 1 + rng.below(4);
    ModelConfig {
        name: "quality-prop".into(),
        vocab: 32 + rng.below(64),
        d_model,
        n_layers,
        n_heads,
        d_ff: d_model * 2,
        seg,
        mem,
        k_assoc,
        dpfp_nu: nu,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim,
        phi_dim: 2 * nu * k_assoc,
        seg_total: seg + mem,
    }
}

fn logit_bits(r: &Response) -> Vec<Vec<u32>> {
    r.logits
        .as_ref()
        .expect("want_logits was set")
        .iter()
        .map(|t| t.data().iter().map(|x| x.to_bits()).collect())
        .collect()
}

/// Policy off, single request: the diagonal engine with the always-on
/// monitor is bit-identical to the sequential oracle at worker thread
/// counts 1 and 3, the quality fields stay at their neutral values, and
/// the measured saturation is thread-count-invariant bit for bit.
#[test]
fn off_policy_single_request_matches_sequential_oracle_at_every_thread_count() {
    let mut rng = Rng::new(0x0FF1);
    for case in 0..6 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let s = 1 + rng.below(6);
        let n = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
        let prompt: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut req = GenerateRequest::new(1, prompt);
        if rng.below(2) == 1 {
            req = req.generate(cfg.seg);
        }
        req.want_logits = true;
        // Half the cases spell the default out, proving `Off` and
        // "unset" are the same request.
        if rng.below(2) == 1 {
            req = req.with_overflow(OverflowPolicy::Off);
        }

        let mut oracle = InferenceEngine::new(
            NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
            ExecMode::Sequential,
        );
        let want = oracle.process(&req).unwrap();

        let mut saturation_ref: Option<u64> = None;
        for threads in [1usize, 3] {
            let backend =
                NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)).with_threads(threads);
            let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal);
            let got = engine.process(&req).unwrap();
            let ctx = format!("case {case} threads {threads} cfg {cfg:?}");

            assert_eq!(logit_bits(&got), logit_bits(&want), "logits drifted: {ctx}");
            assert_eq!(got.generated, want.generated, "{ctx}");
            assert_eq!(got.greedy_tail, want.greedy_tail, "{ctx}");
            assert_eq!(got.segments_skipped, 0, "{ctx}");
            assert!(!got.overflow_routed, "{ctx}");
            assert_eq!(engine.stats_handle().segments_skipped.get(), 0, "{ctx}");
            assert_eq!(engine.stats_handle().overflow_routed.get(), 0, "{ctx}");

            assert!(
                got.saturation > 0.0 && got.saturation <= 1.0,
                "saturation {} out of range: {ctx}",
                got.saturation
            );
            match saturation_ref {
                None => saturation_ref = Some(got.saturation.to_bits()),
                Some(bits) => assert_eq!(
                    got.saturation.to_bits(),
                    bits,
                    "saturation measurement drifted with thread count: {ctx}"
                ),
            }
        }
    }
}

/// Policy off, packed lanes: requests served through a multi-lane
/// wavefront emit the same bytes — and the same per-request saturation
/// — as solo sequential runs. Packing shares compute, never memory or
/// monitor state.
#[test]
fn off_policy_packed_lanes_match_solo_runs() {
    let mut rng = Rng::new(0x0FF2);
    for case in 0..4 {
        let cfg = random_config(&mut rng);
        cfg.validate().unwrap();
        let seed = rng.next_u64();
        let n_requests = 3 + rng.below(3);
        let requests: Vec<GenerateRequest> = (0..n_requests)
            .map(|i| {
                let s = 1 + rng.below(5);
                let n = s * cfg.seg - rng.below(cfg.seg.min(3)); // ragged tails too
                let prompt: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
                let mut req = GenerateRequest::new(i as u64, prompt);
                req.want_logits = true;
                req
            })
            .collect();

        let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(n_requests);
        for req in &requests {
            queue.push((req.clone(), req.id)).unwrap();
        }
        queue.close();
        let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, seed));
        let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(2);
        let mut done: Vec<(u64, Response)> = Vec::new();
        engine
            .serve_queue(&queue, |t, ev| match ev {
                Event::Done { stats } => done.push((*t, *stats)),
                Event::Error { error } => panic!("case {case}: request {t} failed: {error}"),
                _ => {}
            })
            .unwrap();
        assert_eq!(done.len(), n_requests, "case {case}");
        assert_eq!(engine.stats_handle().segments_skipped.get(), 0, "case {case}");
        assert_eq!(engine.stats_handle().overflow_routed.get(), 0, "case {case}");
        done.sort_by_key(|(id, _)| *id);

        for (id, got) in &done {
            let req = &requests[*id as usize];
            let ctx = format!("case {case} req {id} cfg {cfg:?}");
            let mut seq_oracle = InferenceEngine::new(
                NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
                ExecMode::Sequential,
            );
            let want = seq_oracle.process(req).unwrap();
            assert_eq!(logit_bits(got), logit_bits(&want), "packed logits drifted: {ctx}");
            assert_eq!(got.greedy_tail, want.greedy_tail, "{ctx}");
            assert_eq!(got.segments_skipped, 0, "{ctx}");
            assert!(!got.overflow_routed, "{ctx}");
            // The saturation measurement is schedule-shaped (the energy
            // deltas between exits cover different cell sets under
            // sequential vs diagonal execution), so the bit-equality
            // oracle for a packed lane is a SOLO DIAGONAL run — packing
            // must not leak other lanes into the signals.
            let mut diag_solo = InferenceEngine::new(
                NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
                ExecMode::Diagonal,
            );
            let solo = diag_solo.process(req).unwrap();
            assert_eq!(
                got.saturation.to_bits(),
                solo.saturation.to_bits(),
                "packed saturation drifted from the solo diagonal run: {ctx}"
            );
        }
    }
}
