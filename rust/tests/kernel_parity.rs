//! The GEMM kernel tier's two contracts, enforced end to end:
//!
//! * **Exactness** — the cache-blocked SIMD f32 kernels are
//!   byte-identical (`f32::to_bits`, not approx-eq) to the scalar
//!   oracle across ragged shapes, all four matmul variants, and whole
//!   sessions run under either policy.
//! * **Bounded error** — the f16/bf16/int8 weight stores round-trip
//!   within their checked-in budgets, and prepared-f32 weights change
//!   nothing at all.
//!
//! Tests force policies explicitly (`matmul_scalar` / `matmul_blocked`
//! or `set_kernel_policy`) and never assert the ambient default, so the
//! CI `PALLAS_KERNEL=scalar` pass and the default pass both run clean.

use diagonal_batching::config::ModelConfig;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::scheduler::{Executor, ScheduleMode};
use diagonal_batching::tensor::{
    self, matmul_at_blocked, matmul_at_scalar, matmul_blocked, matmul_bt_blocked,
    matmul_bt_scalar, matmul_rows_blocked, matmul_rows_scalar, matmul_scalar, KernelPolicy,
    Precision, Rng, Tensor, WeightMat,
};

/// Ragged shape grid around the JTILE=32 column-tile boundary: 1, odd,
/// tile-1, tile, tile+1, and comfortably-larger in every dimension.
const SHAPES: [(usize, usize, usize); 8] = [
    (1, 1, 1),
    (3, 5, 31),
    (4, 7, 32),
    (2, 9, 33),
    (5, 31, 65),
    (7, 32, 96),
    (1, 33, 17),
    (6, 64, 130),
];

fn assert_bits_eq(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
    }
}

/// Inputs with the hostile cases the skip-zero scalar loops special-case:
/// exact zeros (skipped rows), negative zeros (NOT skipped — `-0.0 == 0.0`
/// is true, so both paths must agree on whatever they do), and a mix of
/// magnitudes.
fn hostile_pair(m: usize, k: usize, n: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let mut b = Tensor::randn(&[k, n], 1.0, &mut rng);
    for (i, v) in a.data_mut().iter_mut().enumerate() {
        if i % 7 == 3 {
            *v = 0.0;
        }
        if i % 11 == 5 {
            *v = -0.0;
        }
    }
    b.data_mut()[0] = -0.0;
    (a, b)
}

/// All four variants, whole ragged grid: blocked == scalar to the bit.
#[test]
fn blocked_kernels_bitexact_across_ragged_shapes() {
    for (si, &(m, k, n)) in SHAPES.iter().enumerate() {
        let ctx = format!("{m}x{k}x{n}");
        let (a, b) = hostile_pair(m, k, n, 0xB10C + si as u64);
        assert_bits_eq(&matmul_scalar(&a, &b), &matmul_blocked(&a, &b), &ctx);

        // Row-range variant, full range and a strict sub-range.
        assert_bits_eq(
            &matmul_rows_scalar(&a, &b, 0, m),
            &matmul_rows_blocked(&a, &b, 0, m),
            &format!("{ctx} rows 0..{m}"),
        );
        if m > 1 {
            assert_bits_eq(
                &matmul_rows_scalar(&a, &b, 1, m - 1),
                &matmul_rows_blocked(&a, &b, 1, m - 1),
                &format!("{ctx} rows 1..{}", m - 1),
            );
        }

        let at = a.t();
        assert_bits_eq(
            &matmul_at_scalar(&at, &b),
            &matmul_at_blocked(&at, &b),
            &format!("{ctx} A^T"),
        );
        let bt = b.t();
        assert_bits_eq(
            &matmul_bt_scalar(&a, &bt),
            &matmul_bt_blocked(&a, &bt),
            &format!("{ctx} B^T"),
        );
    }
}

/// Proptest-style randomized sweep: many seeds, random small shapes, no
/// hand-picked structure — byte equality must hold for all of them.
#[test]
fn blocked_kernels_bitexact_randomized() {
    let mut shape_rng = Rng::new(0x5EED);
    for round in 0..40u64 {
        let m = 1 + shape_rng.below(9);
        let k = 1 + shape_rng.below(70);
        let n = 1 + shape_rng.below(70);
        let (a, b) = hostile_pair(m, k, n, 0xF00D + round);
        assert_bits_eq(
            &matmul_scalar(&a, &b),
            &matmul_blocked(&a, &b),
            &format!("round {round}: {m}x{k}x{n}"),
        );
    }
}

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "kernel-parity".into(),
        vocab: 32,
        d_model: 16,
        n_layers: 3,
        n_heads: 2,
        d_ff: 24,
        seg: 4,
        mem: 2,
        k_assoc: 4,
        dpfp_nu: 2,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 8,
        phi_dim: 16,
        seg_total: 6,
    }
}

/// Whole sessions under each policy: an end-to-end diagonal run under
/// the blocked tier must bit-match the same run under the scalar
/// oracle. Saves and restores the ambient policy.
#[test]
fn end_to_end_session_bitexact_under_both_policies() {
    let c = tiny_cfg();
    let toks: Vec<u32> = (0..5 * c.seg as u32).map(|t| (t * 3 + 1) % c.vocab as u32).collect();
    let run = |policy: KernelPolicy| {
        tensor::set_kernel_policy(policy);
        let mut b = NativeBackend::new(c.clone(), Params::random(&c, 5));
        Executor::new(&mut b, ScheduleMode::Diagonal).run(&toks).unwrap()
    };
    let prev = tensor::kernel_policy();
    let scalar = run(KernelPolicy::Scalar);
    let blocked = run(KernelPolicy::Blocked);
    tensor::set_kernel_policy(prev);
    assert_eq!(scalar.logits.len(), blocked.logits.len());
    for (s, (a, b)) in scalar.logits.iter().zip(&blocked.logits).enumerate() {
        assert_bits_eq(a, b, &format!("segment {s}"));
    }
}

/// Preparing weights at f32 is a pure repacking: backends with and
/// without prepared-f32 weights produce byte-identical sessions.
#[test]
fn prepared_f32_session_bitexact() {
    let c = tiny_cfg();
    let toks: Vec<u32> = (0..3 * c.seg as u32).map(|t| (t * 7 + 2) % c.vocab as u32).collect();
    // NativeBackend::new always prepares f32; the raw-params path is
    // the executor over a backend whose Params were never prepared —
    // reachable via with_precision(F32) being a no-op re-preparation.
    let mut b1 = NativeBackend::new(c.clone(), Params::random(&c, 9));
    let want = Executor::new(&mut b1, ScheduleMode::Sequential).run(&toks).unwrap();
    let mut b2 =
        NativeBackend::new(c.clone(), Params::random(&c, 9)).with_precision(Precision::F32);
    let got = Executor::new(&mut b2, ScheduleMode::Diagonal).run(&toks).unwrap();
    for (s, (a, b)) in want.logits.iter().zip(&got.logits).enumerate() {
        assert_bits_eq(a, b, &format!("segment {s}"));
    }
}

/// Weight round-trip error budgets per precision, on realistic
/// randn-scaled weights.
#[test]
fn quantized_roundtrip_error_within_budget() {
    let mut rng = Rng::new(0x0DD);
    let w = Tensor::randn(&[48, 64], 0.5, &mut rng);
    for (prec, bound) in
        [(Precision::F16, 1e-3f32), (Precision::Bf16, 1e-2), (Precision::Int8, 1e-2)]
    {
        let m = WeightMat::from_tensor(&w, prec);
        assert_eq!(m.precision(), prec);
        let rel = w.rel_error(&m.dequantize());
        assert!(rel < bound, "{prec}: round-trip rel error {rel} over {bound}");
    }
    // f32 storage is lossless, bit for bit.
    let m = WeightMat::from_tensor(&w, Precision::F32);
    assert_bits_eq(&w, &m.dequantize(), "f32 store");
}

/// End-to-end quantized sessions stay within a sane drift envelope of
/// the f32 run (the per-cell budgets live in the unit tests; across a
/// recurrent multi-segment session error compounds, so this bound is
/// looser — it catches blowups, not ULPs).
#[test]
fn quantized_session_drift_bounded() {
    let c = tiny_cfg();
    let toks: Vec<u32> = (0..4 * c.seg as u32).map(|t| (t * 5 + 3) % c.vocab as u32).collect();
    let run = |prec: Precision| {
        let mut b =
            NativeBackend::new(c.clone(), Params::random(&c, 21)).with_precision(prec);
        Executor::new(&mut b, ScheduleMode::Diagonal).run(&toks).unwrap().stacked().unwrap()
    };
    let exact = run(Precision::F32);
    for prec in [Precision::F16, Precision::Bf16, Precision::Int8] {
        let rel = exact.rel_error(&run(prec));
        assert!(rel < 0.5, "{prec}: end-to-end drift {rel}");
        assert!(rel.is_finite(), "{prec}: drift must be finite");
    }
}

/// Quantized + pooled: a 3-thread pool over int8 weights bit-matches
/// the inline int8 run — quantization must not break the pool's
/// determinism contract.
#[test]
fn quantized_pooled_session_bitexact_vs_inline() {
    let c = tiny_cfg();
    let toks: Vec<u32> = (0..4 * c.seg as u32).map(|t| (t * 11 + 1) % c.vocab as u32).collect();
    let run = |threads: usize| {
        let mut b = NativeBackend::new(c.clone(), Params::random(&c, 33))
            .with_precision(Precision::Int8)
            .with_threads(threads);
        Executor::new(&mut b, ScheduleMode::Diagonal).run(&toks).unwrap()
    };
    let inline = run(1);
    let pooled = run(3);
    for (s, (a, b)) in inline.logits.iter().zip(&pooled.logits).enumerate() {
        assert_bits_eq(a, b, &format!("segment {s}"));
    }
}
