//! Fault-injection failover tests for the shard coordinator: workers
//! armed with deterministic [`FaultPlan`]s (die / stall / sever after K
//! protocol frames) must never corrupt a client stream.
//!
//! The sharded parity gate proven here:
//! * a worker killed mid-request fails over to a survivor and the
//!   merged client stream stays FRAME-FOR-FRAME identical to the same
//!   request served by a fault-free shard — greedy requests resume
//!   from the latest usable boundary checkpoint, sampled requests
//!   replay under their seed, and the coordinator's dedup suppresses
//!   every already-forwarded frame;
//! * exactly one terminal frame per request, even across failovers
//!   (checked by pinging on the same connection right after `done` —
//!   a stray duplicate would surface as the ping reply);
//! * a stalled worker trips a bounded `deadline exceeded` error
//!   instead of wedging the coordinator, which keeps serving;
//! * a severed connection is a single failover, not a dead worker:
//!   the process stays healthy and reachable;
//! * in layer-sharded pipelines, a dead stage reloads its range state
//!   onto a survivor and the output stays bit-equal to the
//!   single-process oracle.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
use diagonal_batching::json::Value;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::scheduler::StepBackend;
use diagonal_batching::server::{Client, Server, ServerOptions};
use diagonal_batching::shard::{CoordinatorOptions, FaultPlan, ShardCoordinator};

const SEED: u64 = 0xFA11;

fn cfg() -> ModelConfig {
    ModelConfig::synthetic()
}

fn engine() -> InferenceEngine<NativeBackend> {
    let c = cfg();
    InferenceEngine::new(NativeBackend::new(c.clone(), Params::random(&c, SEED)), ExecMode::Diagonal)
}

/// A lane worker (whole requests) with optional fault injection.
fn worker(fault: Option<FaultPlan>) -> Server {
    Server::start_with(engine(), "127.0.0.1:0", 8, ServerOptions { shard_backend: None, fault })
        .unwrap()
}

/// A layer-range worker (hosts the `shard_*` service too).
fn shard_worker(fault: Option<FaultPlan>) -> Server {
    let c = cfg();
    let backend: Box<dyn StepBackend + Send> =
        Box::new(NativeBackend::new(c.clone(), Params::random(&c, SEED)));
    Server::start_with(
        engine(),
        "127.0.0.1:0",
        8,
        ServerOptions { shard_backend: Some(backend), fault },
    )
    .unwrap()
}

fn coordinator(workers: &[&Server], layer_split: usize) -> ShardCoordinator {
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.to_string()).collect();
    ShardCoordinator::start(
        cfg(),
        &addrs,
        "127.0.0.1:0",
        CoordinatorOptions { layer_split, ..CoordinatorOptions::default() },
    )
    .unwrap()
}

fn prompt(n: usize) -> Vec<u32> {
    (0..n as u32).map(|i| (i * 7 + 3) % 64).collect()
}

/// Stream one request; returns the pre-terminal event frames as
/// canonical JSON plus the `done` frame with the nondeterministic
/// latency removed. Pings on the same connection afterwards: exactly
/// one terminal frame must have been written (a duplicated `done`
/// would be consumed as the ping reply and fail it).
fn streamed(addr: &str, frame: &Value) -> (Vec<String>, Value) {
    let mut client = Client::connect(addr).unwrap();
    let mut events = Vec::new();
    let done = client.request_stream(frame, |ev| events.push(ev.to_json())).unwrap();
    assert!(client.ping().unwrap(), "stray frame after the terminal `done`");
    let mut m = done.as_obj().cloned().unwrap_or_default();
    m.remove("latency_ms");
    (events, Value::Obj(m))
}

/// Abort the whole test binary if `f` wedges: fault handling must be
/// bounded, and a hung coordinator should fail CI loudly, not time out.
fn under_watchdog<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let done = Arc::new(AtomicBool::new(false));
    let d2 = Arc::clone(&done);
    std::thread::spawn(move || {
        for _ in 0..secs * 10 {
            std::thread::sleep(Duration::from_millis(100));
            if d2.load(Ordering::SeqCst) {
                return;
            }
        }
        eprintln!("shard_failover: watchdog fired — coordinator wedged");
        std::process::exit(102);
    });
    let out = f();
    done.store(true, Ordering::SeqCst);
    out
}

#[test]
fn greedy_failover_stream_is_identical_to_fault_free_shard() {
    under_watchdog(120, || {
        // 3 prompt segments + 2 decode segments of frames; the faulty
        // worker (listed first, so round-robin routes request 1 to it)
        // dies mid-stream after 7 frames — past several boundary
        // checkpoints, inside a token batch.
        let frame = Value::obj(vec![
            ("id", Value::Num(42.0)),
            ("tokens", Value::arr_u32(&prompt(24))),
            ("max_new_tokens", Value::Num(10.0)),
        ]);

        let c1 = worker(None);
        let c2 = worker(None);
        let clean = coordinator(&[&c1, &c2], 1);
        let (want_events, want_done) = streamed(&clean.addr.to_string(), &frame);

        let f1 = worker(Some(FaultPlan::DieAfterFrames(7)));
        let f2 = worker(None);
        let faulted = coordinator(&[&f1, &f2], 1);
        let (got_events, got_done) = streamed(&faulted.addr.to_string(), &frame);

        let stats = faulted.stats();
        assert!(stats.shard_failovers.get() >= 1, "the fault never fired");
        // Frame-for-frame: segment and token events survive the
        // failover without gaps, duplicates or reordering.
        assert_eq!(got_events, want_events, "event stream diverged across a failover");
        // The rewritten `done` restores whole-request accounting.
        for field in ["generated", "tokens", "greedy_tail"] {
            assert_eq!(
                got_done.req(field).unwrap(),
                want_done.req(field).unwrap(),
                "done.{field} diverged across a failover"
            );
        }

        clean.stop();
        faulted.stop();
        for w in [c1, c2, f2] {
            w.stop();
        }
        // f1 is fault-dead; its engine thread still drains normally.
        f1.stop();
    });
}

#[test]
fn sampled_failover_replays_identically_under_the_seed() {
    under_watchdog(120, || {
        // Sampled requests have no greedy checkpoint policy: failover is
        // a full seeded replay, and dedup must absorb the replayed
        // prefix frames.
        let frame = Value::obj(vec![
            ("id", Value::Num(43.0)),
            ("tokens", Value::arr_u32(&prompt(16))),
            ("max_new_tokens", Value::Num(10.0)),
            ("temperature", Value::Num(0.85)),
            ("seed", Value::Num(11.0)),
        ]);

        let c1 = worker(None);
        let c2 = worker(None);
        let clean = coordinator(&[&c1, &c2], 1);
        let (want_events, want_done) = streamed(&clean.addr.to_string(), &frame);

        let f1 = worker(Some(FaultPlan::DieAfterFrames(5)));
        let f2 = worker(None);
        let faulted = coordinator(&[&f1, &f2], 1);
        let (got_events, got_done) = streamed(&faulted.addr.to_string(), &frame);

        assert!(faulted.stats().shard_failovers.get() >= 1, "the fault never fired");
        assert_eq!(got_events, want_events, "seeded replay diverged");
        for field in ["generated", "tokens"] {
            assert_eq!(got_done.req(field).unwrap(), want_done.req(field).unwrap());
        }

        clean.stop();
        faulted.stop();
        for w in [c1, c2, f1, f2] {
            w.stop();
        }
    });
}

#[test]
fn stalled_worker_trips_bounded_deadline_error_not_a_wedge() {
    under_watchdog(120, || {
        // Worker 1 stalls 1.5 s before every frame from frame 2 on; the
        // request carries a 200 ms deadline and the coordinator grants
        // 200 ms of grace. The client must get a deadline error in
        // bounded time, and the coordinator must keep serving.
        let f1 = worker(Some(FaultPlan::StallAfterFrames { frames: 2, ms: 1500 }));
        let f2 = worker(None);
        let addrs = [f1.addr.to_string(), f2.addr.to_string()];
        let coord = ShardCoordinator::start(
            cfg(),
            &addrs,
            "127.0.0.1:0",
            CoordinatorOptions {
                layer_split: 1,
                deadline_grace: Duration::from_millis(200),
            },
        )
        .unwrap();

        let mut client = Client::connect(&coord.addr.to_string()).unwrap();
        let frame = Value::obj(vec![
            ("id", Value::Num(44.0)),
            ("tokens", Value::arr_u32(&prompt(24))),
            ("max_new_tokens", Value::Num(8.0)),
            ("deadline_ms", Value::Num(200.0)),
        ]);
        let started = Instant::now();
        let err = client
            .request_stream(&frame, |_| {})
            .expect_err("a stalled worker must not produce a clean done");
        assert!(
            err.to_string().contains("deadline"),
            "expected a deadline error, got: {err}"
        );
        // Bounded: deadline + grace + one best-effort cancel relay,
        // nowhere near the 1.5 s-per-frame stall schedule.
        assert!(
            started.elapsed() < Duration::from_secs(30),
            "deadline error was not bounded: {:?}",
            started.elapsed()
        );

        // Not a wedge: the next request round-robins onto the healthy
        // worker and completes normally.
        let frame2 = Value::obj(vec![
            ("id", Value::Num(45.0)),
            ("tokens", Value::arr_u32(&prompt(16))),
            ("max_new_tokens", Value::Num(4.0)),
        ]);
        let (_events, done) = streamed(&coord.addr.to_string(), &frame2);
        assert_eq!(
            done.req("generated").unwrap().as_u32_vec().unwrap().len(),
            4,
            "coordinator stopped serving after a stalled worker"
        );

        coord.stop();
        f2.stop();
        f1.stop();
    });
}

#[test]
fn dropped_connection_fails_over_once_and_worker_stays_alive() {
    under_watchdog(120, || {
        let frame = Value::obj(vec![
            ("id", Value::Num(46.0)),
            ("tokens", Value::arr_u32(&prompt(24))),
            ("max_new_tokens", Value::Num(8.0)),
        ]);

        let c1 = worker(None);
        let c2 = worker(None);
        let clean = coordinator(&[&c1, &c2], 1);
        let (want_events, want_done) = streamed(&clean.addr.to_string(), &frame);

        // drop_after severs exactly one connection mid-stream; unlike
        // die_after the process keeps accepting afterwards.
        let f1 = worker(Some(FaultPlan::DropAfterFrames(4)));
        let f2 = worker(None);
        let faulted = coordinator(&[&f1, &f2], 1);
        let (got_events, got_done) = streamed(&faulted.addr.to_string(), &frame);

        let stats = faulted.stats();
        assert_eq!(stats.shard_failovers.get(), 1, "one severed conn = one failover");
        assert_eq!(got_events, want_events, "stream diverged across a severed conn");
        for field in ["generated", "tokens", "greedy_tail"] {
            assert_eq!(got_done.req(field).unwrap(), want_done.req(field).unwrap());
        }

        // The dropped worker is a healthy process, not a corpse: it
        // still answers pings directly.
        let mut direct = Client::connect(&f1.addr.to_string()).unwrap();
        assert!(direct.ping().unwrap(), "a severed conn must not kill the worker");

        clean.stop();
        faulted.stop();
        for w in [c1, c2, f1, f2] {
            w.stop();
        }
    });
}

#[test]
fn pipeline_stage_death_reloads_range_state_bit_equal() {
    under_watchdog(120, || {
        let c = cfg();
        // One chain of two layer ranges; the stage-0 worker dies after
        // its init reply + two segment replies, mid-request. The stage
        // must reload its last reported range state onto the survivor.
        let f1 = shard_worker(Some(FaultPlan::DieAfterFrames(3)));
        let f2 = shard_worker(None);
        let coord = coordinator(&[&f1, &f2], 2);

        let tokens = prompt(3 * c.seg);
        let max_new = c.seg;
        let frame = Value::obj(vec![
            ("id", Value::Num(47.0)),
            ("tokens", Value::arr_u32(&tokens)),
            ("max_new_tokens", Value::Num(max_new as f64)),
        ]);
        let (_events, done) = streamed(&coord.addr.to_string(), &frame);

        let stats = coord.stats();
        assert!(stats.shard_failovers.get() >= 1, "the stage death never fired");
        assert!(stats.shard_handoffs.get() >= 1, "failover must hand the range state off");

        // Bit-equal to the single-process oracle with the same weights.
        let mut oracle = InferenceEngine::new(
            NativeBackend::new(c.clone(), Params::random(&c, SEED)),
            ExecMode::Sequential,
        );
        let want = oracle
            .process(&GenerateRequest::new(1, tokens.clone()).generate(max_new))
            .unwrap();
        assert_eq!(
            done.req("generated").unwrap().as_u32_vec().unwrap(),
            want.generated,
            "pipeline output diverged after a stage failover"
        );
        let tail: Vec<usize> = done
            .req("greedy_tail")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(tail, want.greedy_tail);

        coord.stop();
        f2.stop();
        f1.stop();
    });
}
